"""Batched serving engine: wave-admission, early-exit lanes.

A fixed pool of `max_batch` decode lanes runs a single jitted decode step.
Requests are admitted in WAVES of equal prompt length (the queue is bucketed
by length): a wave prefills all its prompts as one batch, then decodes; a
lane whose request finishes (EOS / max_new) stops emitting but its slot
keeps shape (masked out) until the wave drains, at which point the next
wave is admitted.  This is the deployable batch-serving core; true
continuous batching (mid-wave admission) additionally needs PER-LANE
position counters + padded-attention masks in decode_step — documented as
the extension point (the state-surgery splice below already handles the
lane-wise cache insertion it would need).
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # int32 [prompt_len]
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model, params, *, max_batch: int, max_len: int,
                 eos_id: Optional[int] = None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self._buckets: dict = defaultdict(list)   # prompt_len -> [Request]
        self._wave: list = []
        self.state = None
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(lambda p, t: model.prefill(p, t, max_len=max_len))
        self.completed: list = []

    def submit(self, req: Request):
        self._buckets[len(req.prompt)].append(req)

    # ------------------------------------------------------------------ wave

    def _admit_wave(self) -> bool:
        for plen, reqs in sorted(self._buckets.items()):
            if not reqs:
                continue
            wave = [reqs.pop(0) for _ in range(min(self.max_batch, len(reqs)))]
            prompts = np.stack([r.prompt for r in wave])
            if len(wave) < self.max_batch:  # pad lanes with a copy of lane 0
                pad = np.repeat(prompts[:1], self.max_batch - len(wave), axis=0)
                prompts = np.concatenate([prompts, pad])
            logits, self.state = self._prefill(self.params, jnp.asarray(prompts))
            first = np.asarray(jnp.argmax(logits, axis=-1))
            for i, r in enumerate(wave):
                r.out.append(int(first[i]))
            self._wave = wave
            return True
        return False

    def step(self) -> int:
        """One decode step over the live wave; admits a wave when idle."""
        live = [r for r in self._wave if not r.done]
        if not live:
            for r in self._wave:
                self.completed.append(r)
            self._wave = []
            if not self._admit_wave():
                return 0
            live = self._wave
        toks = np.zeros(self.max_batch, np.int32)
        for i, r in enumerate(self._wave):
            toks[i] = r.out[-1]
        logits, self.state = self._decode(self.params, jnp.asarray(toks), self.state)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        emitted = 0
        for i, r in enumerate(self._wave):
            if r.done:
                continue
            t = int(nxt[i])
            r.out.append(t)
            emitted += 1
            if (self.eos_id is not None and t == self.eos_id) or len(r.out) >= r.max_new:
                r.done = True   # lane masked; wave drains, then next admits
        return emitted

    def run_until_drained(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if self.step() == 0:
                break
        return self.completed
