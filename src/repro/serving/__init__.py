"""Serving layer: the LM wave engine and the online embedding engine.

`OnlineEmbeddingEngine` (+ the publisher's `TablePublisher` /
`OnlineTrainer` / delta helpers) is the paper's continuous-online-storage
read path; `ServingEngine` is the LM decode wave engine.
"""

from repro.serving.engine import ServingEngine  # noqa: F401
from repro.serving.embedding_engine import (  # noqa: F401
    EmbeddingRequest,
    EngineMetrics,
    OnlineEmbeddingEngine,
    WaveReport,
)
from repro.serving.publisher import (  # noqa: F401
    OnlineTrainer,
    StaticSource,
    TableDelta,
    TablePublisher,
    TableSource,
    export_delta,
    ingest_delta,
)
