"""OnlineEmbeddingEngine — the paper's title scenario as a serving loop.

Continuous online embedding storage (§1, Fig. 1) means a table that is
read under heavy traffic WHILE an online trainer keeps ingesting and
updating — the read-heavy regime the abstract's headline numbers describe
(3.9 B-KV/s `find`, stable across load factors).  This engine is that
read path, built over ANY `KVTable` handle:

  * `HKVTable` (jnp or kernel backend) — the flat cache-semantic table;
  * `TieredHKVTable` — hot-HBM/cold-hmem hierarchy (DESIGN.md §2.5);
  * `ShardedHKVTable` — the same contract over a device mesh;
  * `DictKVTable` — the dictionary-semantic baselines, for A/B runs.

Wave-batched admission: requests (batches of feature ids) queue and are
packed into fixed-size WAVES of `wave_size` key lanes (EMPTY-padded), so
every wave hits one jit cache entry; a request larger than a wave spans
several.  One wave = one device launch = one host-timed latency sample.

Miss policy (the §3.5 role the read path plays):

  'readonly'  the wave runs `find` — READER role.  Misses return the
              engine's default row (zeros or a caller hook).  On tiered /
              sharded-tiered tables the `promote` flag threads through to
              `find(promote=...)`: promotion re-admits cold hits into the
              hot tier (structural motion on the read path — the
              inclusive-on-access cache), while `promote=False` keeps the
              wave a pure reader.
  'admit'     the wave runs `find_or_insert` — INSERTER role: misses are
              admitted (with the default row as init), so a re-accessed
              key is a hit from its second wave on.  This is the serving
              half of continuous ingestion; at λ=1.0 admission evicts
              low-score entries in place.

Tables are drawn from a `TableSource` (see `repro.serving.publisher`) at
WAVE granularity: each wave reads the source once and — when the policy
mutated the table (admission / promotion) — publishes the successor back.
A snapshot-consistent trainer publishes whole handles; a wave therefore
never observes a half-published table (the consistency model documented
at DESIGN.md §Serving).

Metrics: per-wave hit rate, keys/s, and host-timer latency; `metrics()`
aggregates totals plus p50/p99 wave latency.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import u64
from repro.core.tiered import TieredHKVTable
from repro.core.u64 import U64
from repro.serving.publisher import StaticSource, TableSource

MISS_POLICIES = ("readonly", "admit")


# =============================================================================
# Requests and metrics
# =============================================================================


@dataclasses.dataclass
class EmbeddingRequest:
    """One lookup request: a batch of feature ids awaiting embedding rows."""

    rid: int
    keys: np.ndarray                    # uint64 [n] feature ids
    values: Optional[np.ndarray] = None  # float32 [n, dim] — filled on completion
    found: Optional[np.ndarray] = None   # bool [n]
    done: bool = False


class WaveReport(NamedTuple):
    size: int           # live key lanes served (padding excluded)
    hits: int
    latency_s: float    # host-timed wall clock of the wave launch
    table_version: int  # publisher version the wave was served from
    hot_hits: int = 0   # lanes served from the HOT tier (tiered readonly
                        # waves; == hits elsewhere)
    demotions: int = 0  # REACTIVE hot->cold demotions this wave's own
                        # structural motion caused (tiered admission /
                        # promotion) — the serving-path eviction tax the
                        # maintenance scheduler's proactive rebalancing
                        # exists to drive toward zero (DESIGN.md
                        # §Maintenance)

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.size, 1)

    @property
    def kv_per_s(self) -> float:
        return self.size / max(self.latency_s, 1e-12)


class EngineMetrics(NamedTuple):
    waves: int
    keys: int
    hits: int
    hit_rate: float
    hot_rate: float     # hot-tier serve fraction (== hit_rate off-tier)
    kv_per_s: float     # total keys / total wave wall-clock
    p50_latency_s: float
    p99_latency_s: float
    # reactive serving-path demotions, total and per wave (tiered tables;
    # 0 elsewhere) — the number exp7's scheduler-on/off comparison pins
    reactive_demotions: int = 0
    demotions_per_wave: float = 0.0


# =============================================================================
# The engine
# =============================================================================


class OnlineEmbeddingEngine:
    """Wave-batched embedding lookups over any `KVTable` handle.

        table = TieredHKVTable.create(hot_capacity=8*128,
                                      cold_capacity=64*128, dim=16)
        eng = OnlineEmbeddingEngine(table, wave_size=512,
                                    miss_policy="admit")
        eng.submit(EmbeddingRequest(rid=0, keys=ids))
        eng.run_until_drained()
        print(eng.metrics())

    `table=` may instead be a `TableSource` (e.g. `TablePublisher`), in
    which case every wave serves from the source's latest published
    handle — the train→serve coupling.  `default_row(keys_u64) -> [n,dim]`
    overrides the zero miss-fallback and the admit policy's init rows —
    except on SHARDED tables, whose admit path recomputes init rows
    owner-side from the key (caller rows are not routed); there the hook
    covers only the readonly fallback.
    """

    def __init__(self, table: Any, *, wave_size: int,
                 miss_policy: str = "readonly",
                 promote: Optional[bool] = None,
                 default_row: Optional[Callable[[U64], jax.Array]] = None,
                 scheduler: Optional[Any] = None):
        if miss_policy not in MISS_POLICIES:
            raise ValueError(
                f"miss_policy {miss_policy!r}; one of {MISS_POLICIES}")
        self.source: TableSource = (
            table if isinstance(table, TableSource) else StaticSource(table))
        self.wave_size = wave_size
        self.miss_policy = miss_policy
        self.promote = promote
        self._default_row = default_row
        # wave-interleaved maintenance (repro.maintenance.scheduler):
        # after each wave the scheduler gets the hand-off gap — it
        # snapshots the source, runs one budgeted step, and offers the
        # successor back through the same CAS as admissions.  Maintenance
        # time is the scheduler's own metric, never wave latency.
        self.scheduler = scheduler
        self._queue: deque = deque()      # (request, key offset)
        self._wave_fn = None              # jitted per engine (one cache entry)
        self._mutates = False             # resolved with the wave fn
        self.completed: list = []
        self.reports: list[WaveReport] = []

    # -- admission -------------------------------------------------------------

    def submit(self, req: EmbeddingRequest):
        req.values = None
        req.found = None
        req.done = False
        self._queue.append((req, 0))

    def _admit_wave(self):
        """Pack queued requests into one EMPTY-padded wave of `wave_size`
        lanes.  Returns (keys uint64 [wave_size], segments) where segments
        maps lane ranges back to (request, offset)."""
        lanes = np.full(self.wave_size, _EMPTY_KEY, np.uint64)
        segments = []
        used = 0
        while self._queue and used < self.wave_size:
            req, off = self._queue.popleft()
            take = min(len(req.keys) - off, self.wave_size - used)
            lanes[used:used + take] = req.keys[off:off + take]
            segments.append((req, off, used, take))
            used += take
            if off + take < len(req.keys):   # request spans into the next wave
                self._queue.appendleft((req, off + take))
        return lanes, segments, used

    # -- the wave step ---------------------------------------------------------

    def _build_wave_fn(self, table):
        policy, promote = self.miss_policy, self.promote
        is_tiered = isinstance(table, TieredHKVTable)
        # late import: serving must not pull the distributed layer in for
        # single-device tables
        try:
            from repro.distributed.table_sharding import ShardedHKVTable
            is_sharded = isinstance(table, ShardedHKVTable)
        except Exception:  # pragma: no cover - distributed layer unavailable
            is_sharded = False
        default_row = self._default_row or (
            lambda k: jnp.zeros((k.hi.shape[0], table.dim), jnp.float32))
        # Does this policy mutate the table?  Static: admission always
        # does; a readonly wave only via tiered/sharded promotion.  (An
        # identity check on the jit output would not work — jit rebuilds
        # the handle object even when the state is unchanged.)
        self._mutates = (policy == "admit"
                         or (bool(promote) and (is_tiered or is_sharded)))

        zero = jnp.int32(0)

        def wave(table, kh, kl):
            k = U64(kh, kl)
            init = default_row(k)
            if policy == "admit":
                if is_sharded:
                    # owner shards recompute init rows from the key (the
                    # routed protocol: caller init is not shipped), so the
                    # returned rows ARE the stored rows — `default_row`
                    # applies only to the readonly fallback here
                    r = table.find_or_insert(k)
                    vals = r.values
                else:
                    r = table.find_or_insert(k, init)
                    vals = r.values
                # reactive demotion count: what THIS wave's admissions
                # pushed hot->cold in-line (tiered handles report it)
                dem = getattr(r, "demoted", zero)
                return r.table, vals, r.found, r.found, dem
            # readonly: READER role — default-row fallback on miss.  Wave
            # lookups inherit the handle's backend, so kernel-backed
            # tables serve each wave with the fused find pass
            if is_tiered or is_sharded:
                r = table.find(k, promote=bool(promote))
                succ = r.table if promote else table
            else:
                r = table.find(k)
                succ = table
            vals = jnp.where(r.found[:, None], r.values[:, : table.dim], init)
            dem = getattr(r, "demoted", zero) if promote else zero
            return (succ, vals, r.found, getattr(r, "hot_hit", r.found), dem)

        if is_sharded:
            return wave   # shard_map ops jit internally; outer jit is per-mesh
        return jax.jit(wave)

    def step(self) -> Optional[WaveReport]:
        """Serve one wave; returns its report (None when the queue is idle)."""
        if not self._queue:
            return None
        lanes, segments, used = self._admit_wave()
        version, table = self.source.snapshot()   # ONE read: wave-consistent
        if self._wave_fn is None:
            self._wave_fn = self._build_wave_fn(table)
        k = u64.from_uint64(lanes)
        t0 = time.perf_counter()
        succ, vals, found, hot, dem = self._wave_fn(table, k.hi, k.lo)
        vals, found, hot, dem = jax.block_until_ready((vals, found, hot, dem))
        dt = time.perf_counter() - t0
        if self._mutates:         # admission / promotion built a successor
            self.source.offer(version, succ)
        if self.scheduler is not None:   # between-waves maintenance slot
            self.scheduler.on_wave(self.source)
        vals = np.asarray(vals)
        found = np.asarray(found)
        hot = np.asarray(hot)
        for req, off, lane0, take in segments:
            if req.values is None:
                req.values = np.zeros((len(req.keys), vals.shape[1]),
                                      vals.dtype)
                req.found = np.zeros(len(req.keys), bool)
            req.values[off:off + take] = vals[lane0:lane0 + take]
            req.found[off:off + take] = found[lane0:lane0 + take]
            if off + take == len(req.keys):
                req.done = True
                self.completed.append(req)
        live = ~_is_empty_np(lanes[:used])
        report = WaveReport(size=int(live.sum()),
                            hits=int(found[:used][live].sum()),
                            latency_s=dt, table_version=version,
                            hot_hits=int(hot[:used][live].sum()),
                            demotions=int(dem))
        self.reports.append(report)
        return report

    def run_until_drained(self, max_waves: int = 100_000) -> list:
        for _ in range(max_waves):
            if self.step() is None:
                break
        return self.completed

    # -- metrics ---------------------------------------------------------------

    def metrics(self, *, skip_warmup: bool = True) -> EngineMetrics:
        """Aggregate wave reports.  Counts (waves/keys/hits and the rates)
        cover EVERY wave; the timing aggregates (kv_per_s, p50/p99) skip
        the first wave by default — it pays the jit compile and would
        otherwise dominate the percentiles (`skip_warmup=False` keeps it;
        per-wave numbers incl. the compile wave stay in `self.reports`)."""
        if not self.reports:
            return EngineMetrics(0, 0, 0, 0.0, 0.0, 0.0, 0.0, 0.0)
        keys = sum(r.size for r in self.reports)
        hits = sum(r.hits for r in self.reports)
        demos = sum(r.demotions for r in self.reports)
        timed = (self.reports[1:] if skip_warmup and len(self.reports) > 1
                 else self.reports)
        lat = np.array([r.latency_s for r in timed])
        tkeys = sum(r.size for r in timed)
        return EngineMetrics(
            waves=len(self.reports), keys=keys, hits=hits,
            hit_rate=hits / max(keys, 1),
            hot_rate=sum(r.hot_hits for r in self.reports) / max(keys, 1),
            kv_per_s=tkeys / max(float(lat.sum()), 1e-12),
            p50_latency_s=float(np.percentile(lat, 50)),
            p99_latency_s=float(np.percentile(lat, 99)),
            reactive_demotions=demos,
            demotions_per_wave=demos / max(len(self.reports), 1),
        )


_EMPTY_KEY = u64.EMPTY_KEY


def _is_empty_np(keys: np.ndarray) -> np.ndarray:
    return keys == _EMPTY_KEY
