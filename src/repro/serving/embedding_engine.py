"""OnlineEmbeddingEngine — the paper's title scenario as a serving loop.

Continuous online embedding storage (§1, Fig. 1) means a table that is
read under heavy traffic WHILE an online trainer keeps ingesting and
updating — the read-heavy regime the abstract's headline numbers describe
(3.9 B-KV/s `find`, stable across load factors).  This engine is that
read path, built over ANY `KVTable` handle:

  * `HKVTable` (jnp or kernel backend) — the flat cache-semantic table;
  * `TieredHKVTable` — hot-HBM/cold-hmem hierarchy (DESIGN.md §2.5);
  * `ShardedHKVTable` — the same contract over a device mesh;
  * `DictKVTable` — the dictionary-semantic baselines, for A/B runs.

Admission comes in two modes (`admission=`):

  'wave'        wave-granular (the original contract): requests queue
                whole; each `step()` packs up to `wave_size` key lanes
                (EMPTY-padded), launches, BLOCKS, and unpacks — one
                serial cycle per wave.
  'continuous'  continuous batching: ADMISSION IS DECOUPLED FROM THE
                SERVING CYCLE.  A persistent staging buffer with per-lane
                occupancy tracking splices arriving requests into the
                partially-drained staging wave at `submit()` time, and
                every time the buffer FILLS, the wave dispatches RIGHT
                THERE — asynchronously, without waiting for the engine's
                next `step()` — so a burst's waves queue back-to-back on
                the device instead of one per serving cycle.  The
                host↔device path is double-buffered through a deque of
                in-flight waves: key-packs and result-unpacks happen in
                the async-dispatch gap before `block_until_ready`
                (`poll()` reaps finished waves without blocking; `step()`
                flushes the partial staging wave and reaps).  Handle
                chaining is safe: each wave snapshots the (possibly not
                yet ready) successor the previous wave offered at
                dispatch; XLA orders the launches through the data
                dependency.  Under shallow load the pipeline collapses —
                a lone in-flight wave with nothing staged behind it is
                block-retired in the same step, so light traffic pays
                wave-granular latency and only bursts pipeline.

In both modes every wave is one jit cache entry; a request larger than a
wave spans several, zero-length requests complete without a launch.

Miss policy (the §3.5 role the read path plays):

  'readonly'  the wave runs `find` — READER role.  Misses return the
              engine's default row (zeros or a caller hook).  On tiered /
              sharded-tiered tables the `promote` flag threads through to
              `find(promote=...)`: promotion re-admits cold hits into the
              hot tier (structural motion on the read path — the
              inclusive-on-access cache), while `promote=False` keeps the
              wave a pure reader.
  'admit'     the wave runs `find_or_insert` — INSERTER role: misses are
              admitted (with the default row as init), so a re-accessed
              key is a hit from its second wave on.  This is the serving
              half of continuous ingestion; at λ=1.0 admission evicts
              low-score entries in place.

Served rows are exactly `table.dim` wide under BOTH policies: tables
carrying in-row optimizer state (`aux_value_dim > 0`,
`core/table.py::total_value_dim`) never leak aux columns to clients.

Tables are drawn from a `TableSource` (see `repro.serving.publisher`) at
DISPATCH granularity: each wave reads the source once when it launches
and — when the policy mutated the table (admission / promotion) —
publishes the successor back immediately, so under overlapped staging
the next dispatch chains on the offered (async) handle.  A
snapshot-consistent trainer publishes whole handles; a wave therefore
never observes a half-published table (DESIGN.md §Serving).  The cached
wave closure is keyed on the published table's static signature
(type / backend / dims / score policy): a trainer that publishes a
structurally different successor (flat→tiered retier, backend flip, dim
change) gets a freshly built closure instead of stale static flags.

Metrics split queue-wait from service per REQUEST, on top of the
per-wave numbers:

  queue-wait   submit → dispatch of the first wave carrying the request;
  service      that dispatch → results unpacked into the request;
  total        submit → done (== queue-wait + service).

`metrics()` aggregates per-wave hit rate / keys/s / p50-p99 wave latency
plus the per-request p50/p99 of all three latency components.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import u64
from repro.core.api import table_signature
from repro.core.tiered import TieredHKVTable
from repro.core.u64 import U64
from repro.obs.trace import as_tracer
from repro.serving.publisher import StaticSource, TableSource

MISS_POLICIES = ("readonly", "admit")
ADMISSION_MODES = ("wave", "continuous")


# =============================================================================
# Requests and metrics
# =============================================================================


@dataclasses.dataclass
class EmbeddingRequest:
    """One lookup request: a batch of feature ids awaiting embedding rows."""

    rid: int
    keys: np.ndarray                    # uint64 [n] feature ids
    values: Optional[np.ndarray] = None  # float32 [n, dim] — filled on completion
    found: Optional[np.ndarray] = None   # bool [n]
    done: bool = False
    # SLO accounting (host perf_counter stamps; see module doc)
    t_submit: Optional[float] = None     # stamped by engine.submit()
    t_admit: Optional[float] = None      # dispatch of the first carrying wave
    t_done: Optional[float] = None       # last carrying wave unpacked

    @property
    def queue_wait_s(self) -> float:
        """Time spent queued before the first carrying wave dispatched."""
        if self.t_submit is None or self.t_admit is None:
            return 0.0
        return self.t_admit - self.t_submit

    @property
    def service_s(self) -> float:
        """First dispatch → results unpacked (device + in-flight overlap)."""
        if self.t_admit is None or self.t_done is None:
            return 0.0
        return self.t_done - self.t_admit

    @property
    def total_latency_s(self) -> float:
        """submit → done == queue-wait + service."""
        if self.t_submit is None or self.t_done is None:
            return 0.0
        return self.t_done - self.t_submit


class WaveReport(NamedTuple):
    size: int           # live key lanes served (padding excluded)
    hits: int
    latency_s: float    # host wall clock: dispatch → results ready
    table_version: int  # publisher version the wave was served from
    hot_hits: int = 0   # lanes served from the HOT tier (tiered readonly
                        # waves; == hits elsewhere)
    demotions: int = 0  # REACTIVE hot->cold demotions this wave's own
                        # structural motion caused (tiered admission /
                        # promotion) — the serving-path eviction tax the
                        # maintenance scheduler's proactive rebalancing
                        # exists to drive toward zero (DESIGN.md
                        # §Maintenance)

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.size, 1)

    @property
    def kv_per_s(self) -> float:
        return self.size / max(self.latency_s, 1e-12)


class EngineMetrics(NamedTuple):
    waves: int
    keys: int
    hits: int
    hit_rate: float
    hot_rate: float     # hot-tier serve fraction (== hit_rate off-tier)
    kv_per_s: float     # total keys / total wave wall-clock
    p50_latency_s: float
    p99_latency_s: float
    # reactive serving-path demotions, total and per wave (tiered tables;
    # 0 elsewhere) — the number exp7's scheduler-on/off comparison pins
    reactive_demotions: int = 0
    demotions_per_wave: float = 0.0
    # per-REQUEST SLO split (completed requests; module doc):
    requests: int = 0
    p50_queue_wait_s: float = 0.0
    p99_queue_wait_s: float = 0.0
    p50_service_s: float = 0.0
    p99_service_s: float = 0.0
    p50_total_s: float = 0.0
    p99_total_s: float = 0.0

    @classmethod
    def zero(cls) -> "EngineMetrics":
        """The well-formed empty snapshot (no waves, no requests) —
        field-safe against the NamedTuple growing, unlike a positional
        zero literal."""
        return cls(waves=0, keys=0, hits=0, hit_rate=0.0, hot_rate=0.0,
                   kv_per_s=0.0, p50_latency_s=0.0, p99_latency_s=0.0)


class _Inflight(NamedTuple):
    """A dispatched, not-yet-retired wave (continuous mode holds one)."""

    out: tuple          # (succ, vals, found, hot, dem) — async device values
    segments: list      # (request, key offset, lane0, take)
    used: int
    lanes: np.ndarray
    version: int
    t_dispatch: float


# =============================================================================
# The engine
# =============================================================================


class OnlineEmbeddingEngine:
    """Wave-batched embedding lookups over any `KVTable` handle.

        table = TieredHKVTable.create(hot_capacity=8*128,
                                      cold_capacity=64*128, dim=16)
        eng = OnlineEmbeddingEngine(table, wave_size=512,
                                    miss_policy="admit",
                                    admission="continuous")
        eng.submit(EmbeddingRequest(rid=0, keys=ids))
        eng.run_until_drained()
        print(eng.metrics())

    `table=` may instead be a `TableSource` (e.g. `TablePublisher`), in
    which case every wave serves from the source's latest published
    handle — the train→serve coupling.  `default_row(keys_u64) -> [n,dim]`
    overrides the zero miss-fallback and the admit policy's init rows —
    except on SHARDED tables, whose admit path recomputes init rows
    owner-side from the key (caller rows are not routed); there the hook
    covers only the readonly fallback.

    `host_budget_s` is the between-wave slack budget staging and
    maintenance COMPETE for (ROADMAP): the host time this step spent
    packing/unpacking is charged against it and only the remainder is
    offered to the scheduler, which defers its step when its estimated
    cost exceeds the remaining slack.  `None` (default) leaves the
    scheduler cadence-only (the pre-continuous contract).
    """

    def __init__(self, table: Any, *, wave_size: int,
                 miss_policy: str = "readonly",
                 promote: Optional[bool] = None,
                 default_row: Optional[Callable[[U64], jax.Array]] = None,
                 scheduler: Optional[Any] = None,
                 admission: str = "wave",
                 host_budget_s: Optional[float] = None,
                 tracer: Optional[Any] = None):
        if miss_policy not in MISS_POLICIES:
            raise ValueError(
                f"miss_policy {miss_policy!r}; one of {MISS_POLICIES}")
        if admission not in ADMISSION_MODES:
            raise ValueError(
                f"admission {admission!r}; one of {ADMISSION_MODES}")
        self.source: TableSource = (
            table if isinstance(table, TableSource) else StaticSource(table))
        self.wave_size = wave_size
        self.miss_policy = miss_policy
        self.promote = promote
        self.admission = admission
        self.host_budget_s = host_budget_s
        self._default_row = default_row
        # span tracing (repro.obs.trace): engine.submit / wave.splice /
        # wave.dispatch / wave.reap / request lifetimes.  `as_tracer`
        # normalizes None to the shared noop so call sites stay
        # unconditional.
        self.tracer = as_tracer(tracer)
        # wave-interleaved maintenance (repro.maintenance.scheduler):
        # after each wave the scheduler gets the hand-off gap — it
        # snapshots the source, runs one budgeted step, and offers the
        # successor back through the same CAS as admissions.  Maintenance
        # time is the scheduler's own metric, never wave latency.
        self.scheduler = scheduler
        self._queue: deque = deque()      # (request, key offset)
        # staging buffer: the NEXT wave, with per-lane occupancy — a
        # spanning request's remainder and fresh arrivals splice into its
        # free lanes between steps (continuous mode packs it eagerly)
        self._stage_lanes = np.full(wave_size, _EMPTY_KEY, np.uint64)
        self._stage_segments: list = []
        self._stage_used = 0
        self._stage_age = 0               # steps a partial stage has waited
        self._flights: deque = deque()    # dispatched, not yet retired
        self._wave_fn = None              # jitted; keyed on table signature
        self._wave_sig = None
        self._mutates = False             # resolved with the wave fn
        self.completed: list = []
        self.reports: list[WaveReport] = []

    # -- admission -------------------------------------------------------------

    def submit(self, req: EmbeddingRequest):
        req.values = None
        req.found = None
        req.done = False
        if req.t_submit is None:
            req.t_submit = time.perf_counter()
        self.tracer.instant("engine.submit", rid=req.rid, keys=len(req.keys))
        self._queue.append((req, 0))
        if self.admission == "continuous":
            # splice into the partially-drained staging wave right away:
            # free lanes don't wait for the next step's pack — and every
            # wave the splice FILLS dispatches immediately (async), so a
            # burst chains onto the device without waiting out the
            # serving cycle
            while True:
                self._fill_staging()
                if self._stage_used < self.wave_size:
                    break
                lanes, segments, used = self._take_staging()
                flight = self._dispatch(lanes, segments, used)
                if flight is not None:
                    self._flights.append(flight)

    @property
    def idle(self) -> bool:
        return (not self._queue and self._stage_used == 0
                and not self._stage_segments and not self._flights)

    def _fill_staging(self):
        """Move queued keys into the staging buffer's free lanes (per-lane
        occupancy: `_stage_used` is the first free lane)."""
        while self._queue and self._stage_used < self.wave_size:
            req, off = self._queue.popleft()
            take = min(len(req.keys) - off, self.wave_size - self._stage_used)
            lane0 = self._stage_used
            self._stage_lanes[lane0:lane0 + take] = req.keys[off:off + take]
            self._stage_segments.append((req, off, lane0, take))
            self._stage_used += take
            if off + take < len(req.keys):   # spans into the next wave
                self._queue.appendleft((req, off + take))
                break

    def _take_staging(self):
        """Claim the staged wave and reset the buffer for the next one."""
        with self.tracer.span("wave.splice"):
            self._fill_staging()
        lanes, segments, used = (self._stage_lanes, self._stage_segments,
                                 self._stage_used)
        self._stage_lanes = np.full(self.wave_size, _EMPTY_KEY, np.uint64)
        self._stage_segments = []
        self._stage_used = 0
        self._stage_age = 0
        return lanes, segments, used

    # -- the wave step ---------------------------------------------------------

    def _build_wave_fn(self, table):
        policy, promote = self.miss_policy, self.promote
        is_tiered = isinstance(table, TieredHKVTable)
        # late import: serving must not pull the distributed layer in for
        # single-device tables
        try:
            from repro.distributed.table_sharding import ShardedHKVTable
            is_sharded = isinstance(table, ShardedHKVTable)
        except Exception:  # pragma: no cover - distributed layer unavailable
            is_sharded = False
        default_row = self._default_row or (
            lambda k: jnp.zeros((k.hi.shape[0], table.dim), jnp.float32))
        # Does this policy mutate the table?  Static: admission always
        # does; a readonly wave only via tiered/sharded promotion.  (An
        # identity check on the jit output would not work — jit rebuilds
        # the handle object even when the state is unchanged.)
        self._mutates = (policy == "admit"
                         or (bool(promote) and (is_tiered or is_sharded)))

        zero = jnp.int32(0)

        def wave(table, kh, kl):
            k = U64(kh, kl)
            init = default_row(k)
            if policy == "admit":
                if is_sharded:
                    # owner shards recompute init rows from the key (the
                    # routed protocol: caller init is not shipped), so the
                    # returned rows ARE the stored rows — `default_row`
                    # applies only to the readonly fallback here
                    r = table.find_or_insert(k)
                else:
                    r = table.find_or_insert(k, init)
                # clients get exactly dim columns: rows on aux-carrying
                # tables (total_value_dim > dim) keep optimizer state
                # server-side
                vals = r.values[:, : table.dim]
                # reactive demotion count: what THIS wave's admissions
                # pushed hot->cold in-line (tiered handles report it)
                dem = getattr(r, "demoted", zero)
                return r.table, vals, r.found, r.found, dem
            # readonly: READER role — default-row fallback on miss.  Wave
            # lookups inherit the handle's backend, so kernel-backed
            # tables serve each wave with the fused find pass
            if is_tiered or is_sharded:
                r = table.find(k, promote=bool(promote))
                succ = r.table if promote else table
            else:
                r = table.find(k)
                succ = table
            vals = jnp.where(r.found[:, None], r.values[:, : table.dim], init)
            dem = getattr(r, "demoted", zero) if promote else zero
            return (succ, vals, r.found, getattr(r, "hot_hit", r.found), dem)

        if is_sharded:
            return wave   # shard_map ops jit internally; outer jit is per-mesh
        return jax.jit(wave)

    def _wave_fn_for(self, table):
        """The compiled wave closure for this table, rebuilt when the
        published handle's static signature changed (type / backend /
        dims / score policy) — a trainer may retier or reshape the table
        mid-stream and the closure's baked-in flags must follow."""
        sig = table_signature(table)
        if self._wave_fn is None or sig != self._wave_sig:
            self._wave_fn = self._build_wave_fn(table)
            self._wave_sig = sig
        return self._wave_fn

    def _dispatch(self, lanes, segments, used) -> Optional[_Inflight]:
        """Launch one wave asynchronously (no block).  Zero-live waves
        (only zero-length requests) complete immediately without a
        launch."""
        version, table = self.source.snapshot()  # ONE read: wave-consistent
        if used == 0:
            now = time.perf_counter()
            for req, _off, _lane0, _take in segments:
                req.values = np.zeros((0, table.dim), np.float32)
                req.found = np.zeros(0, bool)
                req.t_admit = req.t_admit or now
                req.t_done = now
                req.done = True
                self.completed.append(req)
                self.tracer.complete_abs("request", req.t_submit, now,
                                         rid=req.rid, keys=len(req.keys))
            return None
        fn = self._wave_fn_for(table)
        k = u64.from_uint64(lanes)
        t0 = time.perf_counter()
        with self.tracer.span("wave.dispatch", used=used, version=version):
            out = fn(table, k.hi, k.lo)
            if self._mutates:     # admission / promotion built a successor;
                # offer the (possibly still computing) handle NOW so the next
                # dispatch chains on it — XLA orders launches by data deps
                self.source.offer(version, out[0])
        for req, _off, _lane0, _take in segments:
            if req.t_admit is None:
                req.t_admit = t0
        return _Inflight(out=out, segments=segments, used=used, lanes=lanes,
                         version=version, t_dispatch=t0)

    def _retire(self, flight: _Inflight) -> WaveReport:
        """Block on a dispatched wave, unpack results into its requests."""
        with self.tracer.span("wave.reap", used=flight.used,
                              version=flight.version):
            _succ, vals, found, hot, dem = flight.out
            vals, found, hot, dem = jax.block_until_ready(
                (vals, found, hot, dem))
            dt = time.perf_counter() - flight.t_dispatch
            vals = np.asarray(vals)
            found = np.asarray(found)
            hot = np.asarray(hot)
            now = time.perf_counter()
            for req, off, lane0, take in flight.segments:
                if req.values is None:
                    req.values = np.zeros((len(req.keys), vals.shape[1]),
                                          vals.dtype)
                    req.found = np.zeros(len(req.keys), bool)
                req.values[off:off + take] = vals[lane0:lane0 + take]
                req.found[off:off + take] = found[lane0:lane0 + take]
                if off + take == len(req.keys):
                    req.done = True
                    req.t_done = now
                    self.completed.append(req)
                    # the request's full submit→done lifetime, from the
                    # engine's own SLO stamps (raw perf_counter epoch)
                    self.tracer.complete_abs("request", req.t_submit, now,
                                             rid=req.rid, keys=len(req.keys))
        used = flight.used
        live = ~_is_empty_np(flight.lanes[:used])
        report = WaveReport(size=int(live.sum()),
                            hits=int(found[:used][live].sum()),
                            latency_s=dt, table_version=flight.version,
                            hot_hits=int(hot[:used][live].sum()),
                            demotions=int(dem))
        self.reports.append(report)
        return report

    def _maintenance_slot(self, staging_s: float):
        """The between-wave hand-off gap: staging already spent
        `staging_s` of the host budget; maintenance competes for the
        remainder (one budget — ROADMAP's slack contract)."""
        if self.scheduler is None:
            return
        slack = None
        if self.host_budget_s is not None:
            slack = max(0.0, self.host_budget_s - staging_s)
        try:
            self.scheduler.on_wave(self.source, slack_s=slack)
        except TypeError:   # older scheduler without the slack seam
            self.scheduler.on_wave(self.source)

    def step(self) -> Optional[WaveReport]:
        """Serve one wave; returns its report.

        'wave' mode: pack → dispatch → block → unpack, serially (None
        when the queue is idle).  'continuous' mode: flush the partial
        staging wave (waves the splice filled already dispatched at
        submit), reap finished flights without blocking, and
        block-retire the oldest wave when draining or when a lone
        shallow-load wave is in flight (pipeline collapse).  The report
        may cover an earlier wave than the one dispatched this step;
        None when nothing retired (check `.idle` to drive draining, or
        use `run_until_drained`)."""
        if self.idle:
            return None
        t_host0 = time.perf_counter()
        if self.admission == "wave":
            lanes, segments, used = self._take_staging()
            flight = self._dispatch(lanes, segments, used)
            pack_s = time.perf_counter() - t_host0
            report = self._retire(flight) if flight is not None else None
            self._maintenance_slot(pack_s)
            return report
        # continuous: full waves already dispatched at submit.  The
        # PARTIAL staging wave flushes when the pipeline is SHALLOW
        # (<= 1 in flight: the device has spare capacity, so a padded
        # wave costs no one anything) or once it has waited out two
        # whole steps without filling (the straggler cap — a lone
        # request must not wait out a deep drain).  While the pipeline
        # is deep, staged keys keep accepting splices so backlog
        # traffic rides densely packed waves: EMPTY-padded lanes cost
        # full compute, and flushing every step at half fill would put
        # the device at saturation and grow the chain without bound
        flight = None
        if ((self._queue or self._stage_used or self._stage_segments)
                and (len(self._flights) <= 1 or self._stage_age >= 2)):
            lanes, segments, used = self._take_staging()
            flight = self._dispatch(lanes, segments, used)
            if flight is not None:
                self._flights.append(flight)
        elif self._stage_used or self._stage_segments:
            self._stage_age += 1
        pack_s = time.perf_counter() - t_host0
        # non-blocking reap of finished waves, in chain order
        report = None
        reaped = False
        while self._flights and _flight_ready(self._flights[0]):
            report = self._retire(self._flights.popleft())
            reaped = True
        if self._flights and flight is None and not reaped:
            # nothing dispatched, nothing ready: block on the oldest so
            # every step makes progress (the drain path)
            report = self._retire(self._flights.popleft())
        elif (flight is not None and len(self._flights) == 1
                and not self._queue and self._stage_used == 0
                and not self._stage_segments):
            # pipeline collapse: a lone shallow-load wave with nothing
            # staged behind it retires in the step it dispatched —
            # wave-granular latency instead of waiting out a reap cycle
            report = self._retire(self._flights.popleft())
        unpack_s = time.perf_counter() - t_host0 - pack_s
        self._maintenance_slot(pack_s + unpack_s)
        return report

    def poll(self) -> Optional[WaveReport]:
        """Non-blocking reap: retire every in-flight wave whose results
        are ready, without dispatching anything.  The event-loop seam for
        continuous admission — callers waiting on arrivals poll between
        submits so finished waves complete their requests at device pace
        rather than at the serving-cycle cadence.  Returns the last
        retired wave's report (None if nothing was ready)."""
        report = None
        while self._flights and _flight_ready(self._flights[0]):
            report = self._retire(self._flights.popleft())
        return report

    def run_until_drained(self, max_waves: int = 100_000) -> list:
        for _ in range(max_waves):
            self.step()
            if self.idle:
                break
        return self.completed

    # -- metrics ---------------------------------------------------------------

    def metrics(self, *, skip_warmup: bool = True) -> EngineMetrics:
        """Aggregate wave reports + per-request SLO latencies.  Counts
        (waves/keys/hits and the rates) cover EVERY wave; the timing
        aggregates (kv_per_s, wave p50/p99) skip the first wave by
        default — it pays the jit compile and would otherwise dominate
        the percentiles (`skip_warmup=False` keeps it; per-wave numbers
        incl. the compile wave stay in `self.reports`).  The per-request
        queue-wait / service / total percentiles cover every COMPLETED
        request (including warmup — queue-wait is a property of arrival
        pressure, not of compilation)."""
        if not self.reports and not self.completed:
            return EngineMetrics.zero()
        keys = sum(r.size for r in self.reports)
        hits = sum(r.hits for r in self.reports)
        demos = sum(r.demotions for r in self.reports)
        timed = (self.reports[1:] if skip_warmup and len(self.reports) > 1
                 else self.reports)
        lat = (np.array([r.latency_s for r in timed]) if timed
               else np.zeros(1))
        tkeys = sum(r.size for r in timed)
        reqs = [r for r in self.completed if r.t_done is not None]
        qw = np.array([r.queue_wait_s for r in reqs]) if reqs else np.zeros(1)
        sv = np.array([r.service_s for r in reqs]) if reqs else np.zeros(1)
        tot = (np.array([r.total_latency_s for r in reqs]) if reqs
               else np.zeros(1))
        return EngineMetrics(
            waves=len(self.reports), keys=keys, hits=hits,
            hit_rate=hits / max(keys, 1),
            hot_rate=sum(r.hot_hits for r in self.reports) / max(keys, 1),
            kv_per_s=tkeys / max(float(lat.sum()), 1e-12),
            p50_latency_s=float(np.percentile(lat, 50)),
            p99_latency_s=float(np.percentile(lat, 99)),
            reactive_demotions=demos,
            demotions_per_wave=demos / max(len(self.reports), 1),
            requests=len(reqs),
            p50_queue_wait_s=float(np.percentile(qw, 50)),
            p99_queue_wait_s=float(np.percentile(qw, 99)),
            p50_service_s=float(np.percentile(sv, 50)),
            p99_service_s=float(np.percentile(sv, 99)),
            p50_total_s=float(np.percentile(tot, 50)),
            p99_total_s=float(np.percentile(tot, 99)),
        )


_EMPTY_KEY = u64.EMPTY_KEY


def _is_empty_np(keys: np.ndarray) -> np.ndarray:
    return keys == _EMPTY_KEY


def _flight_ready(flight: _Inflight) -> bool:
    """True when a dispatched wave's device results are ready (its
    retire would not block).  Conservative on backends without
    `is_ready`: report not-ready and let the blocking paths retire."""
    try:
        return all(x.is_ready()
                   for x in jax.tree_util.tree_leaves(flight.out[1:]))
    except AttributeError:  # pragma: no cover - backend without is_ready
        return False
