"""Dictionary-semantic GPU hash-table baselines (paper §5.1, Table 1).

JAX re-implementations of the two baseline *families* the paper compares
against, preserving their collision-resolution structure so the load-factor
pathology of Figure 6 / Table 3 reproduces on any hardware:

  OpenAddressingTable  — WarpCore / cuCollections family: linear probing,
                         unbounded probe chains, insert fails at capacity.
  BucketedP2CTable     — BGHT / BP2HT family: 16-slot buckets, power-of-two
                         -choices placement, insert fails when both buckets
                         fill (BP2HT's silent-drop regime at λ→1).

Both are dictionary-semantic: every inserted key must be preserved, no
eviction, so λ=1.0 is a failure regime rather than an operating point.
"""

from repro.baselines.dict_tables import (  # noqa: F401
    BucketedP2CTable,
    DictKVTable,
    DictUpsert,
    OpenAddressingTable,
)
