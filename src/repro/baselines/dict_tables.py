"""Dictionary-semantic baseline hash tables (see package docstring).

Both tables expose the same batched API subset as HKV (insert, find) plus
per-op *probe-transaction counters* — the structural cost metric of paper
Table 3, which is hardware-independent and therefore the honest way to
reproduce the Fig. 6 degradation curves on this CPU container.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import u64
from repro.core.api import dedupe_keys, normalize_keys
from repro.core.merge import EvictionStream
from repro.core.ops import ExportResult
from repro.core.u64 import U64

# Open-addressing DELETED marker (classic tombstone): not EMPTY — probe
# chains continue past it — but claimable by inserts.  One uint64 point is
# sacrificed from the key space, next to the EMPTY sentinel.
TOMB_HI = np.uint32(0xFFFFFFFF)
TOMB_LO = np.uint32(0xFFFFFFFE)


def _rank_rows_flat(key_hi, key_lo, mask, budget: int):
    """First `budget` masked slots of a FLAT key plane in the
    dictionary tables' deterministic sweep order (no score metadata ->
    ascending key).  Returns (rows int32 [budget], lane bool [budget]) —
    the one rank implementation both baselines share."""
    c = key_hi.shape[0]
    iota = jnp.arange(c, dtype=jnp.int32)
    nc, _kh, _kl, rows = jax.lax.sort(
        ((~mask).astype(jnp.uint32), key_hi, key_lo, iota),
        num_keys=3, is_stable=False)
    return rows[:budget], nc[:budget] == 0


def _is_tomb(keys: U64) -> jax.Array:
    return (keys.hi == TOMB_HI) & (keys.lo == TOMB_LO)


class InsertReport(NamedTuple):
    state: "object"
    ok: jax.Array       # bool [N] — False = dictionary-semantic insert FAILURE
    probes: jax.Array   # int32 [N] — memory transactions consumed


class FindReport(NamedTuple):
    values: jax.Array
    found: jax.Array
    probes: jax.Array   # int32 [N]


# =============================================================================
# Open addressing (WarpCore / cuCollections family)
# =============================================================================


class OAState(NamedTuple):
    key_hi: jax.Array   # uint32 [C]
    key_lo: jax.Array
    values: jax.Array   # [C, D]


@dataclasses.dataclass(frozen=True)
class OpenAddressingTable:
    """Linear probing over a flat slot array; probe chains grow with λ.

    max_probe bounds the emulated probe loop (WarpCore's probing is
    unbounded; we cap it at `max_probe` and report failure beyond, which is
    conservative *in the baseline's favor*).
    """

    capacity: int
    dim: int
    max_probe: int = 512

    def create(self) -> OAState:
        c = self.capacity
        return OAState(
            key_hi=jnp.full((c,), u64.EMPTY_HI, jnp.uint32),
            key_lo=jnp.full((c,), u64.EMPTY_LO, jnp.uint32),
            values=jnp.zeros((c, self.dim), jnp.float32),
        )

    def _slot(self, keys: U64, d: jax.Array) -> jax.Array:
        h1, _ = u64.hash_pair(keys)
        c = np.uint32(self.capacity)
        if self.capacity & (self.capacity - 1) == 0:
            return ((h1 + d.astype(jnp.uint32)) & (c - np.uint32(1))).astype(jnp.int32)
        return ((h1 + d.astype(jnp.uint32)) % c).astype(jnp.int32)

    def _probe(self, state: OAState, keys: U64):
        """Scan each key's probe chain until the key or a true EMPTY slot.

        Tombstones (deleted slots) do NOT stop the scan — the key may live
        beyond one — but remain claimable by `insert`.  Returns
        (found, slot, probes)."""
        n = keys.hi.shape[0]
        valid = ~u64.is_empty(keys)

        def cond(carry):
            done, found, slot_at, d, probes = carry
            return jnp.any(~done) & (d < self.max_probe)

        def body(carry):
            done, found, slot_at, d, probes = carry
            active = ~done
            slot = self._slot(keys, jnp.where(active, d, 0))
            occ = U64(state.key_hi[slot], state.key_lo[slot])
            probes = probes + active.astype(jnp.int32)
            hit = u64.eq(occ, keys) & active
            miss_stop = u64.is_empty(occ) & active   # definitive miss at empty
            found = found | hit
            slot_at = jnp.where(hit, slot, slot_at)
            done = done | hit | miss_stop
            return done, found, slot_at, d + 1, probes

        carry = (
            ~valid,
            jnp.zeros((n,), bool),
            jnp.zeros((n,), jnp.int32),
            jnp.int32(0),
            jnp.zeros((n,), jnp.int32),
        )
        done, found, slot_at, _, probes = jax.lax.while_loop(cond, body, carry)
        return found, slot_at, probes

    def insert(self, state: OAState, keys: U64, values: jax.Array) -> InsertReport:
        """Batched linear-probe insert, resolving intra-batch claims like the
        CAS race it emulates: lowest batch index wins a contested slot.

        Two phases, like a real tombstone-aware OA table: a full probe pass
        first (so an existing key beyond a tombstone updates in place rather
        than duplicating into the tombstone), then a claim loop over
        empty-or-tombstone slots for the remaining misses.

        Probe accounting: the structural cost is ONE chain scan per key —
        a real implementation remembers the first free slot during that
        scan — so only the phase-1 probes count; the claim loop re-walks
        already-scanned slots and adds none (keeps `avg_probes`
        comparable with the paper's single-scan metric, exp1)."""
        n = keys.hi.shape[0]
        valid = ~u64.is_empty(keys)
        found, fslot, probes = self._probe(state, keys)
        urow = jnp.where(found, fslot, self.capacity)
        state = state._replace(
            values=state.values.at[urow].set(values, mode="drop"))

        def cond(carry):
            state, placed, d = carry
            return jnp.any(~placed) & (d < self.max_probe)

        def body(carry):
            state, placed, d = carry
            active = ~placed
            dist = jnp.where(active, d, 0)
            slot = self._slot(keys, dist)
            occ_key = U64(state.key_hi[slot], state.key_lo[slot])
            is_self = u64.eq(occ_key, keys) & active      # a round-winner's write
            free = (u64.is_empty(occ_key) | _is_tomb(occ_key)) & active
            # claim resolution: among batch entries claiming the same free
            # slot this round, the lowest batch index wins (CAS emulation)
            idx = jnp.arange(n, dtype=jnp.int32)
            claim_slot = jnp.where(free, slot, self.capacity)
            winner = jnp.full((self.capacity + 1,), n, jnp.int32).at[claim_slot].min(idx)
            won = free & (winner[jnp.clip(claim_slot, 0, self.capacity)] == idx)
            write = is_self | won
            wslot = jnp.where(write, slot, self.capacity)
            state = OAState(
                key_hi=state.key_hi.at[wslot].set(keys.hi, mode="drop"),
                key_lo=state.key_lo.at[wslot].set(keys.lo, mode="drop"),
                values=state.values.at[wslot].set(values, mode="drop"),
            )
            placed = placed | write
            d = d + 1
            return state, placed, d

        carry = (state, ~valid | found, jnp.int32(0))
        state, placed, _ = jax.lax.while_loop(cond, body, carry)
        return InsertReport(state=state, ok=placed, probes=probes)

    def find(self, state: OAState, keys: U64) -> FindReport:
        found, slot_at, probes = self._probe(state, keys)
        vals = jnp.where(found[:, None], state.values[slot_at], 0.0)
        return FindReport(values=vals, found=found, probes=probes)

    def assign(self, state: OAState, keys: U64, values: jax.Array) -> OAState:
        """Write values of existing keys in place; misses are no-ops."""
        found, slot, _probes = self._probe(state, keys)
        row = jnp.where(found, slot, self.capacity)
        return state._replace(
            values=state.values.at[row].set(values, mode="drop"))

    def erase(self, state: OAState, keys: U64) -> OAState:
        """Tombstone found keys (probe chains through them stay intact)."""
        found, slot, _probes = self._probe(state, keys)
        row = jnp.where(found, slot, self.capacity)
        n = keys.hi.shape[0]
        return OAState(
            key_hi=state.key_hi.at[row].set(jnp.full((n,), TOMB_HI), mode="drop"),
            key_lo=state.key_lo.at[row].set(jnp.full((n,), TOMB_LO), mode="drop"),
            values=state.values.at[row].set(
                jnp.zeros((n, self.dim), state.values.dtype), mode="drop"),
        )

    # -- maintenance sweeps (predicate over keys; no score metadata) -----------

    def sweep_mask(self, state: OAState, pred) -> jax.Array:
        """bool [C] — live (non-tomb) slots matching `pred`.  Dictionary
        tables carry no scores; the predicate sees zero score planes."""
        k = U64(state.key_hi, state.key_lo)
        z = jnp.zeros_like(state.key_hi)
        live = ~u64.is_empty(k) & ~_is_tomb(k)
        return pred.matches(k, U64(z, z)) & live

    def erase_mask(self, state: OAState, mask: jax.Array) -> OAState:
        """Tombstone every slot where mask (bulk form of `erase`)."""
        return OAState(
            key_hi=jnp.where(mask, TOMB_HI, state.key_hi),
            key_lo=jnp.where(mask, TOMB_LO, state.key_lo),
            values=jnp.where(mask[:, None], 0.0, state.values),
        )

    def rank_rows(self, state: OAState, mask: jax.Array, budget: int):
        return _rank_rows_flat(state.key_hi, state.key_lo, mask, budget)


# =============================================================================
# Bucketed power-of-two-choices (BGHT / BP2HT family, 16-slot buckets)
# =============================================================================


class P2CState(NamedTuple):
    key_hi: jax.Array   # uint32 [B, 16]
    key_lo: jax.Array
    values: jax.Array   # [B*16, D]


@dataclasses.dataclass(frozen=True)
class BucketedP2CTable:
    """BGHT/BP2HT-like: two candidate 16-slot buckets per key, load-based
    choice, NO eviction — both-full means the insert silently fails (the
    BP2HT λ=1.0 regime where only 48 % of inserts succeed)."""

    capacity: int
    dim: int
    slots: int = 16

    def __post_init__(self):
        assert self.capacity % self.slots == 0

    @property
    def num_buckets(self) -> int:
        return self.capacity // self.slots

    def create(self) -> P2CState:
        b, s = self.num_buckets, self.slots
        return P2CState(
            key_hi=jnp.full((b, s), u64.EMPTY_HI, jnp.uint32),
            key_lo=jnp.full((b, s), u64.EMPTY_LO, jnp.uint32),
            values=jnp.zeros((b * s, self.dim), jnp.float32),
        )

    def _buckets(self, keys: U64) -> tuple[jax.Array, jax.Array]:
        h1, h2 = u64.hash_pair(keys)
        nb = np.uint32(self.num_buckets)
        if self.num_buckets & (self.num_buckets - 1) == 0:
            return (
                (h1 & (nb - np.uint32(1))).astype(jnp.int32),
                (h2 & (nb - np.uint32(1))).astype(jnp.int32),
            )
        return (h1 % nb).astype(jnp.int32), (h2 % nb).astype(jnp.int32)

    def _match(self, state: P2CState, bucket: jax.Array, keys: U64):
        hit = (state.key_hi[bucket] == keys.hi[:, None]) & (
            state.key_lo[bucket] == keys.lo[:, None]
        )
        return jnp.any(hit, axis=1), jnp.argmax(hit, axis=1).astype(jnp.int32)

    def insert(self, state: P2CState, keys: U64, values: jax.Array) -> InsertReport:
        n, s = keys.hi.shape[0], self.slots
        valid = ~u64.is_empty(keys)
        b1, b2 = self._buckets(keys)
        # update path (2 bucket loads)
        h1, s1 = self._match(state, b1, keys)
        h2, s2 = self._match(state, b2, keys)
        hitb = jnp.where(h1, b1, b2)
        hits = jnp.where(h1, s1, s2)
        hit = (h1 | h2) & valid
        row = jnp.where(hit, hitb * s + hits, self.capacity)
        state = P2CState(
            key_hi=state.key_hi,
            key_lo=state.key_lo,
            values=state.values.at[row].set(values, mode="drop"),
        )
        # insert path: load-based two-choice, rank-resolved within batch.
        # Placement iterates rounds so that keys bounced from an overfull
        # round-1 target retry against refreshed occupancy — emulating the
        # sequential CAS race the GPU baselines run (a one-shot batch
        # placement would overflow buckets sequential P2C balances).
        miss0 = valid & ~hit
        iota = jnp.arange(n, dtype=jnp.int32)

        def cond(carry):
            state, pending, progress, rounds = carry
            return jnp.any(pending) & progress & (rounds < 32)

        def body(carry):
            state, pending, progress, rounds = carry
            occ = jnp.sum(
                (~u64.is_empty(U64(state.key_hi, state.key_lo))).astype(jnp.int32), axis=1
            )
            target = jnp.where(occ[b2] < occ[b1], b2, b1)
            tb = jnp.where(pending, target, self.num_buckets).astype(jnp.int32)
            order = jnp.argsort(tb)
            tb_s = tb[order]
            is_new = jnp.concatenate([jnp.ones((1,), bool), tb_s[1:] != tb_s[:-1]])
            rank = iota - jax.lax.cummax(jnp.where(is_new, iota, -1))
            free_slot = occ[jnp.clip(tb_s, 0, self.num_buckets - 1)] + rank
            ok_ins = (tb_s < self.num_buckets) & (free_slot < s)
            wb = jnp.where(ok_ins, tb_s, self.num_buckets)
            ws = jnp.clip(free_slot, 0, s - 1)
            keys_s = U64(keys.hi[order], keys.lo[order])
            state = P2CState(
                key_hi=state.key_hi.at[wb, ws].set(keys_s.hi, mode="drop"),
                key_lo=state.key_lo.at[wb, ws].set(keys_s.lo, mode="drop"),
                values=state.values.at[
                    jnp.where(ok_ins, wb * s + ws, self.capacity)
                ].set(values[order], mode="drop"),
            )
            placed = jnp.zeros((n,), bool).at[order].set(ok_ins)
            return state, pending & ~placed, jnp.any(placed), rounds + 1

        state, pending, _, _ = jax.lax.while_loop(
            cond, body, (state, miss0, jnp.bool_(True), jnp.int32(0))
        )
        ok = hit | (miss0 & ~pending)
        probes = jnp.where(valid, 2 + miss0.astype(jnp.int32), 0)
        return InsertReport(state=state, ok=ok, probes=probes)

    def find(self, state: P2CState, keys: U64) -> FindReport:
        valid = ~u64.is_empty(keys)
        b1, b2 = self._buckets(keys)
        h1, s1 = self._match(state, b1, keys)
        h2, s2 = self._match(state, b2, keys)
        found = (h1 | h2) & valid
        # structural cost: always 2 bucket loads (b1 then b2) unless hit in b1
        probes = jnp.where(h1, 1, 2) * valid.astype(jnp.int32)
        row = jnp.where(h1, b1 * self.slots + s1, b2 * self.slots + s2)
        vals = jnp.where(found[:, None], state.values[jnp.clip(row, 0, self.capacity - 1)], 0.0)
        return FindReport(values=vals, found=found, probes=probes)

    def _locate(self, state: P2CState, keys: U64):
        """(found, row) over both candidate buckets."""
        valid = ~u64.is_empty(keys)
        b1, b2 = self._buckets(keys)
        h1, s1 = self._match(state, b1, keys)
        h2, s2 = self._match(state, b2, keys)
        found = (h1 | h2) & valid
        row = jnp.where(h1, b1 * self.slots + s1, b2 * self.slots + s2)
        return found, row

    def assign(self, state: P2CState, keys: U64, values: jax.Array) -> P2CState:
        """Write values of existing keys in place; misses are no-ops."""
        found, row = self._locate(state, keys)
        return state._replace(values=state.values.at[
            jnp.where(found, row, self.capacity)
        ].set(values, mode="drop"))

    def _compact(self, key_hi, key_lo, values) -> P2CState:
        """Stable per-bucket compaction: live slots first, order preserved
        — restores the invariant `insert` relies on (new entries land at
        slot index == occupancy count)."""
        b, s = self.num_buckets, self.slots
        order = jnp.argsort(u64.is_empty(U64(key_hi, key_lo)),
                            axis=1, stable=True)
        rows = (jnp.arange(b, dtype=jnp.int32)[:, None] * s
                + order.astype(jnp.int32)).reshape(-1)
        return P2CState(
            key_hi=jnp.take_along_axis(key_hi, order, axis=1),
            key_lo=jnp.take_along_axis(key_lo, order, axis=1),
            values=values[rows],
        )

    def erase(self, state: P2CState, keys: U64) -> P2CState:
        """Remove found keys, then re-pack every bucket densely (see
        `_compact` — the invariant a sequential CAS table keeps by
        swapping with the last live slot)."""
        found, row = self._locate(state, keys)
        w = jnp.where(found, row, self.capacity)
        b, s = self.num_buckets, self.slots
        key_hi = state.key_hi.reshape(-1).at[w].set(u64.EMPTY_HI, mode="drop")
        key_lo = state.key_lo.reshape(-1).at[w].set(u64.EMPTY_LO, mode="drop")
        values = state.values.at[w].set(
            jnp.zeros((keys.hi.shape[0], self.dim), state.values.dtype),
            mode="drop")
        return self._compact(key_hi.reshape(b, s), key_lo.reshape(b, s),
                             values)

    # -- maintenance sweeps (predicate over keys; no score metadata) -----------

    def sweep_mask(self, state: P2CState, pred) -> jax.Array:
        """bool [B, S] — live slots matching `pred` (zero score planes)."""
        k = U64(state.key_hi, state.key_lo)
        z = jnp.zeros_like(state.key_hi)
        return pred.matches(k, U64(z, z)) & ~u64.is_empty(k)

    def erase_mask(self, state: P2CState, mask: jax.Array) -> P2CState:
        """Bulk erase by [B, S] mask, then re-pack every bucket."""
        key_hi = jnp.where(mask, jnp.uint32(u64.EMPTY_HI), state.key_hi)
        key_lo = jnp.where(mask, jnp.uint32(u64.EMPTY_LO), state.key_lo)
        values = jnp.where(mask.reshape(-1)[:, None], 0.0, state.values)
        return self._compact(key_hi, key_lo, values)

    def rank_rows(self, state: P2CState, mask: jax.Array, budget: int):
        return _rank_rows_flat(state.key_hi.reshape(-1),
                               state.key_lo.reshape(-1),
                               mask.reshape(-1), budget)


# =============================================================================
# KVTable-protocol handle over either baseline (repro.core.api.KVTable)
# =============================================================================


class DictUpsert(NamedTuple):
    table: "DictKVTable"
    ok: jax.Array       # bool [N] — placement success (dictionary semantics)
    probes: jax.Array   # int32 [N]


class DictFindOrInsert(NamedTuple):
    table: "DictKVTable"
    values: jax.Array   # [N, dim] — stored row on hit, init row otherwise
    found: jax.Array    # bool [N] — key existed before the op
    ok: jax.Array       # bool [N] — key present after the op
    probes: jax.Array   # int32 [N]


class DictSweep(NamedTuple):
    table: "DictKVTable"
    swept: jax.Array    # int32 [] — entries removed by the sweep


class DictEvictIf(NamedTuple):
    table: "DictKVTable"
    evicted: EvictionStream   # rank-aligned; scores zero (no metadata)
    count: jax.Array    # int32 []


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DictKVTable:
    """Handle binding a baseline's state to its implementation dataclass.

    Implements the same `KVTable` protocol as `repro.core.HKVTable`, so the
    benchmark harness drives HKV and the dictionary-semantic baselines
    through one code path.  The capability gap the paper measures remains
    visible through `.ok`: at capacity these tables FAIL inserts where HKV
    evicts in place.
    """

    state: object                 # OAState | P2CState (the pytree leaf struct)
    impl: object                  # OpenAddressingTable | BucketedP2CTable (static)

    def tree_flatten(self):
        return (self.state,), (self.impl,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(state=children[0], impl=aux[0])

    # -- construction ----------------------------------------------------------

    @classmethod
    def open_addressing(cls, capacity: int, dim: int, **kw) -> "DictKVTable":
        impl = OpenAddressingTable(capacity=capacity, dim=dim, **kw)
        return cls(state=impl.create(), impl=impl)

    @classmethod
    def bucketed_p2c(cls, capacity: int, dim: int, **kw) -> "DictKVTable":
        impl = BucketedP2CTable(capacity=capacity, dim=dim, **kw)
        return cls(state=impl.create(), impl=impl)

    def with_state(self, state) -> "DictKVTable":
        return dataclasses.replace(self, state=state)

    # -- KVTable protocol ------------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.impl.capacity

    @property
    def dim(self) -> int:
        return self.impl.dim

    def find(self, keys) -> FindReport:
        return self.impl.find(self.state, normalize_keys(keys))

    def insert_or_assign(self, keys, values) -> DictUpsert:
        # handle-level dedupe (last writer wins), matching the HKV closure's
        # batch contract: the batched claim emulations below would otherwise
        # place within-batch duplicates twice
        k = normalize_keys(keys)
        d = dedupe_keys(k)
        rep = self.impl.insert(self.state, d.unique, values[d.last_index])
        return DictUpsert(table=self.with_state(rep.state),
                          ok=rep.ok[d.inverse] & ~u64.is_empty(k),
                          probes=rep.probes[d.inverse])

    def find_or_insert(self, keys, init_values) -> DictFindOrInsert:
        """Lookup; insert `init_values` for missing keys (no admission
        control: dictionary semantics — a full table FAILS the insert and
        `ok` is False where the key is absent afterwards)."""
        k = normalize_keys(keys)
        d = dedupe_keys(k)
        f = self.impl.find(self.state, d.unique)
        init_u = init_values[d.last_index]
        miss = ~f.found & ~u64.is_empty(d.unique)
        mk = U64(jnp.where(miss, d.unique.hi, jnp.uint32(u64.EMPTY_HI)),
                 jnp.where(miss, d.unique.lo, jnp.uint32(u64.EMPTY_LO)))
        rep = self.impl.insert(self.state, mk, init_u)
        vals_u = jnp.where(f.found[:, None], f.values, init_u)
        valid = ~u64.is_empty(k)
        return DictFindOrInsert(
            table=self.with_state(rep.state),
            values=vals_u[d.inverse],
            found=f.found[d.inverse] & valid,
            ok=(f.found | rep.ok)[d.inverse] & valid,
            # one chain scan per key (the insert's internal probe pass
            # re-walks the slots this find already scanned)
            probes=f.probes[d.inverse],
        )

    def assign(self, keys, values) -> "DictKVTable":
        """Updater: write values of existing keys; misses are no-ops."""
        k = normalize_keys(keys)
        d = dedupe_keys(k)
        return self.with_state(
            self.impl.assign(self.state, d.unique, values[d.last_index]))

    def erase(self, keys) -> "DictKVTable":
        return self.with_state(
            self.impl.erase(self.state, normalize_keys(keys)))

    def clear(self) -> "DictKVTable":
        return self.with_state(self.impl.create())

    def contains(self, keys) -> jax.Array:
        return self.find(keys).found

    # -- maintenance (KVTable sweep surface; DESIGN.md §Maintenance) -----------
    #
    # Dictionary tables carry no score metadata: predicates evaluate
    # against zero score planes (key predicates work unchanged; score
    # predicates are the caller's lookout — see the conformance capability
    # table), and evict_if's "coldest first" order degenerates to
    # ascending key.

    def erase_if(self, pred) -> DictSweep:
        m = self.impl.sweep_mask(self.state, pred)
        return DictSweep(
            table=self.with_state(self.impl.erase_mask(self.state, m)),
            swept=jnp.sum(m.astype(jnp.int32)))

    def evict_if(self, pred, budget: int) -> DictEvictIf:
        c = self.capacity
        if budget < 1:
            raise ValueError(f"budget must be >= 1; got {budget}")
        budget = min(budget, c)
        m = self.impl.sweep_mask(self.state, pred)
        rows, lane = self.impl.rank_rows(self.state, m, budget)
        khi = self.state.key_hi.reshape(-1)
        klo = self.state.key_lo.reshape(-1)
        vals = self.state.values[jnp.where(lane, rows, 0)]
        z = jnp.zeros((budget,), jnp.uint32)
        stream = EvictionStream(
            key_hi=jnp.where(lane, khi[rows], 0),
            key_lo=jnp.where(lane, klo[rows], 0),
            values=jnp.where(lane[:, None], vals, jnp.zeros_like(vals)),
            score_hi=z, score_lo=z, mask=lane,
        )
        em = jnp.zeros((c,), bool).at[
            jnp.where(lane, rows, c)].set(True, mode="drop")
        t2 = self.with_state(
            self.impl.erase_mask(self.state, em.reshape(m.shape)))
        return DictEvictIf(table=t2, evicted=stream,
                           count=jnp.sum(lane.astype(jnp.int32)))

    def stats(self):
        """`TableStats` over the export-view bucket space (scores absent —
        quantiles report zero)."""
        from repro.maintenance import stats as stats_mod  # deferred: layering

        khi = self.state.key_hi.reshape(-1)
        klo = self.state.key_lo.reshape(-1)
        if isinstance(self.impl, BucketedP2CTable):
            w = self.impl.slots
        else:
            w = _OA_EXPORT_SLOTS
        pad = (-len(khi)) % w
        if pad:
            khi = jnp.concatenate([khi, jnp.full((pad,), u64.EMPTY_HI, jnp.uint32)])
            klo = jnp.concatenate([klo, jnp.full((pad,), u64.EMPTY_LO, jnp.uint32)])
        kh2, kl2 = khi.reshape(-1, w), klo.reshape(-1, w)
        k = U64(kh2, kl2)
        return stats_mod.stats_from_planes(
            kh2, kl2, live=~u64.is_empty(k) & ~_is_tomb(k))

    def size(self) -> jax.Array:
        khi = self.state.key_hi
        klo = self.state.key_lo
        k = U64(khi, klo)
        live = ~u64.is_empty(k) & ~_is_tomb(k)
        return jnp.sum(live.astype(jnp.int32))

    def load_factor(self) -> jax.Array:
        return self.size().astype(jnp.float32) / float(self.capacity)

    # -- export (checkpoint/publisher path) ------------------------------------

    @property
    def num_buckets(self) -> int:
        """Export-view bucket count (OA: 128-slot chunks of the flat array;
        P2C: its native 16-slot buckets)."""
        if isinstance(self.impl, BucketedP2CTable):
            return self.impl.num_buckets
        return -(-self.capacity // _OA_EXPORT_SLOTS)

    def export_batch(self, bucket_start: int, bucket_count: int) -> ExportResult:
        """Stream a contiguous bucket range (dictionary tables carry no
        scores — the score planes export as zeros)."""
        if isinstance(self.impl, BucketedP2CTable):
            s = self.impl.slots
            sl = slice(bucket_start, bucket_start + bucket_count)
            khi = self.state.key_hi[sl].reshape(-1)
            klo = self.state.key_lo[sl].reshape(-1)
            rows = self.state.values[bucket_start * s:
                                     (bucket_start + bucket_count) * s]
        else:
            sl = slice(bucket_start * _OA_EXPORT_SLOTS,
                       (bucket_start + bucket_count) * _OA_EXPORT_SLOTS)
            khi = self.state.key_hi[sl]
            klo = self.state.key_lo[sl]
            rows = self.state.values[sl]
        k = U64(khi, klo)
        zeros = jnp.zeros(khi.shape, jnp.uint32)
        return ExportResult(key_hi=khi, key_lo=klo, values=rows,
                            score_hi=zeros, score_lo=zeros,
                            mask=~u64.is_empty(k) & ~_is_tomb(k))


_OA_EXPORT_SLOTS = 128
