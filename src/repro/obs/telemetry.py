"""Device op telemetry — typed counters for the core op families.

The paper's headline claims are observability claims: find throughput
"stable across load factors 0.50–1.00 (<5% variation)" and in-place
eviction instead of capacity failure are only checkable with per-op
counters.  This module computes those counters ON DEVICE, as a pure
observer over the same probe/match formulas the ops themselves use
(`find.probe_keys` + `find.match_lanes` — the single key-match oracle),
so the jnp and kernel backends report identical numbers by construction.

Wiring contract (enforced by `tests/test_obs.py` and the hkv-lint
`telemetry` checker):

  * every `@roles.*`-annotated op in `repro.core.ops` takes an optional
    keyword-only `telemetry=` argument (or carries an explicit exemption
    in `repro.analysis.telemetry.TELEMETRY_EXEMPT`);
  * `telemetry=None` (the default) is LITERALLY the pre-telemetry code
    path: zero extra launches, zero jaxpr growth, results untouched;
  * `telemetry=sink` records an `OpTelemetry` pytree per op call into the
    sink.  Results stay bit-identical — the observer never feeds back.

Counter semantics (all int32 device scalars):

  lanes            valid (non-EMPTY) key lanes in the batch
  hits / misses    keys found resident / not (pre-op state for inserters)
  probed_buckets   bucket rows FETCHED by the batch implementation: the
                   vectorized probe reads both candidate rows in
                   dual-bucket mode (1 + [bucket2 != bucket1] per valid
                   lane — the `meta_rows` term of exp1), one in single
  probed_slots     probed_buckets × slots_per_bucket
  digest_pass      occupied probed slots passing the 8-bit digest
                   prefilter (the slots that go on to a full 64-bit
                   compare; ≈ hits + ~1/256 false positives)
  second_probe     valid lanes whose bucket-1 row did NOT resolve them —
                   the serialized second-probe demand a sequential
                   implementation would pay (dual-bucket mode only)
  updated/inserted/evicted/rejected
                   upsert status histogram: in-place update, fresh-slot
                   insert, insert-by-eviction, admission rejection
  swept            entries removed by a predicated sweep / erase
  promoted/demoted/dropped
                   tier motion (cold→hot promotion, hot→cold demotion,
                   pairs lost at the cold boundary) — recorded by the
                   tier hierarchy (`core/tiered.py`)

Under `jax.jit`, create the sink INSIDE the jitted function and return
`sink.total()` (or `sink.by_op`) as an output — the recorded values are
tracers and must leave through the function's return value.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import find as find_mod
from repro.core import u64
from repro.core.merge import (STATUS_EVICTED, STATUS_INSERTED,
                              STATUS_REJECTED, STATUS_UPDATED)
from repro.core.table import HKVConfig, HKVState
from repro.core.u64 import U64

_COUNTERS = (
    "lanes", "hits", "misses",
    "probed_buckets", "probed_slots", "digest_pass", "second_probe",
    "updated", "inserted", "evicted", "rejected", "swept",
    "promoted", "demoted", "dropped",
)


class OpTelemetry(NamedTuple):
    """One op call's device-computed counters (int32 scalars; a pytree —
    returnable from jit, summable across shards with `lax.psum`)."""

    lanes: jax.Array
    hits: jax.Array
    misses: jax.Array
    probed_buckets: jax.Array
    probed_slots: jax.Array
    digest_pass: jax.Array
    second_probe: jax.Array
    updated: jax.Array
    inserted: jax.Array
    evicted: jax.Array
    rejected: jax.Array
    swept: jax.Array
    promoted: jax.Array
    demoted: jax.Array
    dropped: jax.Array

    @classmethod
    def zero(cls) -> "OpTelemetry":
        z = jnp.int32(0)
        return cls(*([z] * len(_COUNTERS)))

    @classmethod
    def of(cls, **counters) -> "OpTelemetry":
        """Build from a subset of named counters (the rest zero)."""
        z = jnp.int32(0)
        return cls(**{name: counters.get(name, z) for name in _COUNTERS})

    def merge(self, other: "OpTelemetry") -> "OpTelemetry":
        return OpTelemetry(*[a + b for a, b in zip(self, other)])

    def to_dict(self) -> dict:
        """Host-side {counter: int} (blocks on the device values)."""
        return {name: int(v) for name, v in zip(_COUNTERS, self)}

    def rates(self) -> dict:
        """Host-side derived rates (the claim-anchoring numbers):

          probes_per_query    probed_buckets / lanes — exp1's meta_rows
                              term, the λ-stability claim's flat curve
          digest_pass_rate    digest_pass / probed_slots — the prefilter's
                              full-compare escape fraction
          second_probe_rate   second_probe / lanes — dual-bucket serial
                              probe demand
          hit_rate            hits / lanes
        """
        d = self.to_dict()
        lanes = max(d["lanes"], 1)
        return {
            "probes_per_query": d["probed_buckets"] / lanes,
            "digest_pass_rate": d["digest_pass"] / max(d["probed_slots"], 1),
            "second_probe_rate": d["second_probe"] / lanes,
            "hit_rate": d["hits"] / lanes,
        }


class TelemetrySink:
    """Accumulates `OpTelemetry` records keyed by op name.

    Outside jit the recorded counters are concrete device scalars;
    inside jit they are tracers — create the sink inside the traced
    function and return `sink.total()` as an output.
    """

    def __init__(self):
        self.by_op: dict[str, OpTelemetry] = {}
        self.calls: dict[str, int] = {}

    def record(self, op: str, tel: OpTelemetry) -> None:
        prev = self.by_op.get(op)
        self.by_op[op] = tel if prev is None else prev.merge(tel)
        self.calls[op] = self.calls.get(op, 0) + 1

    def total(self) -> OpTelemetry:
        tel = OpTelemetry.zero()
        for t in self.by_op.values():
            tel = tel.merge(t)
        return tel

    def snapshot(self) -> dict:
        """Host-side {op: {counter: int}} (blocks on device values)."""
        return {op: t.to_dict() for op, t in sorted(self.by_op.items())}

    def __bool__(self) -> bool:  # a sink with no records is still a sink
        return True


# =============================================================================
# Observers — pure counter math over (pre-op state, keys, op outputs)
# =============================================================================


def probe_counters(state: HKVState, cfg: HKVConfig, keys: U64) -> dict:
    """The probe-side counters every keyed op family shares, computed
    from the SAME formulas the ops use (`probe_keys` + `match_lanes`) —
    backend-independent by construction.

    `probed_buckets` counts bucket rows the batch implementation fetches
    (both candidate rows in dual mode — exp1's meta_rows term, flat
    across λ); `second_probe` counts lanes bucket 1 failed to resolve
    (the sequential implementation's conditional second fetch).
    """
    probe = find_mod.probe_keys(cfg, keys)
    valid = probe.valid
    s = cfg.slots_per_bucket
    khi1 = state.key_hi[probe.bucket1]
    klo1 = state.key_lo[probe.bucket1]
    if cfg.use_digest:
        m1 = find_mod.match_lanes(khi1, klo1, keys.hi[:, None],
                                  keys.lo[:, None],
                                  state.digests[probe.bucket1],
                                  probe.digest[:, None])
    else:
        m1 = find_mod.match_lanes(khi1, klo1, keys.hi[:, None],
                                  keys.lo[:, None])
    hit1 = jnp.any(m1, axis=1) & valid
    occ1 = ~u64.is_empty(U64(khi1, klo1))
    pass1 = ((state.digests[probe.bucket1] == probe.digest[:, None])
             & occ1 & valid[:, None])
    digest_pass = jnp.sum(pass1.astype(jnp.int32))
    n_valid = jnp.sum(valid.astype(jnp.int32))
    if cfg.buckets_per_key == 2:
        distinct2 = valid & (probe.bucket2 != probe.bucket1)
        probed = n_valid + jnp.sum(distinct2.astype(jnp.int32))
        second = jnp.sum((valid & ~hit1).astype(jnp.int32))
        khi2 = state.key_hi[probe.bucket2]
        klo2 = state.key_lo[probe.bucket2]
        occ2 = ~u64.is_empty(U64(khi2, klo2))
        pass2 = ((state.digests[probe.bucket2] == probe.digest[:, None])
                 & occ2 & distinct2[:, None])
        digest_pass = digest_pass + jnp.sum(pass2.astype(jnp.int32))
    else:
        probed = n_valid
        second = jnp.int32(0)
    return {
        "lanes": n_valid,
        "probed_buckets": probed,
        "probed_slots": probed * jnp.int32(s),
        "digest_pass": digest_pass,
        "second_probe": second,
    }


def _with_hits(state, cfg, keys, found) -> dict:
    c = probe_counters(state, cfg, keys)
    valid = ~u64.is_empty(keys)
    hits = jnp.sum((found & valid).astype(jnp.int32))
    c["hits"] = hits
    c["misses"] = c["lanes"] - hits
    return c


def observe_find(state: HKVState, cfg: HKVConfig, keys: U64,
                 found: jax.Array) -> OpTelemetry:
    """Reader-family observer (find / find_ptr / find_rows / contains)."""
    return OpTelemetry.of(**_with_hits(state, cfg, keys, found))


def observe_update(state: HKVState, cfg: HKVConfig, keys: U64,
                   found: jax.Array) -> OpTelemetry:
    """Updater-family observer (assign*, update_rows): a resident lane's
    row/score write counts as `updated`.  `state` is the PRE-op state (the
    probe ran against its planes)."""
    c = _with_hits(state, cfg, keys, found)
    c["updated"] = c["hits"]
    return OpTelemetry.of(**c)


def observe_upsert(state: HKVState, cfg: HKVConfig, keys: U64,
                   status: jax.Array,
                   found: Optional[jax.Array] = None) -> OpTelemetry:
    """Inserter-family observer: probe counters against the PRE-op state
    plus the merge-status histogram — the eviction-vs-admission-rejection
    split the paper's cache-semantics claim rides on.  `found` (when the
    op reports it, e.g. find_or_insert) overrides the hit derivation;
    otherwise a hit is an in-place update (STATUS_UPDATED)."""
    c = probe_counters(state, cfg, keys)
    updated = jnp.sum((status == STATUS_UPDATED).astype(jnp.int32))
    if found is None:
        hits = updated
    else:
        valid = ~u64.is_empty(keys)
        hits = jnp.sum((found & valid).astype(jnp.int32))
    c["hits"] = hits
    c["misses"] = c["lanes"] - hits
    c["updated"] = updated
    c["inserted"] = jnp.sum((status == STATUS_INSERTED).astype(jnp.int32))
    c["evicted"] = jnp.sum((status == STATUS_EVICTED).astype(jnp.int32))
    c["rejected"] = jnp.sum((status == STATUS_REJECTED).astype(jnp.int32))
    return OpTelemetry.of(**c)


def observe_erase(state: HKVState, cfg: HKVConfig, keys: U64,
                  found: jax.Array) -> OpTelemetry:
    """Keyed-erase observer: each resident key removed counts as swept."""
    c = _with_hits(state, cfg, keys, found)
    c["swept"] = c["hits"]
    return OpTelemetry.of(**c)


def observe_sweep(cfg: HKVConfig, swept: jax.Array) -> OpTelemetry:
    """Predicated whole-table sweep (erase_if): every slot is scanned —
    probed_slots reports the full table pass, not a per-key probe."""
    cap = jnp.int32(cfg.num_buckets * cfg.slots_per_bucket)
    return OpTelemetry.of(
        probed_buckets=jnp.int32(cfg.num_buckets), probed_slots=cap,
        swept=swept.astype(jnp.int32))


def observe_evict_if(cfg: HKVConfig, count: jax.Array) -> OpTelemetry:
    """Budgeted coldest-first eviction sweep."""
    cap = jnp.int32(cfg.num_buckets * cfg.slots_per_bucket)
    return OpTelemetry.of(
        probed_buckets=jnp.int32(cfg.num_buckets), probed_slots=cap,
        evicted=count.astype(jnp.int32), swept=count.astype(jnp.int32))


def tier_motion(promoted=0, demoted=0, dropped=0) -> OpTelemetry:
    """Tier-hierarchy motion record (`core/tiered.py` folds its result
    counters in through this)."""
    i32 = lambda x: jnp.asarray(x, jnp.int32).reshape(())  # noqa: E731
    return OpTelemetry.of(promoted=i32(promoted), demoted=i32(demoted),
                          dropped=i32(dropped))


def psum_telemetry(tel: OpTelemetry, axis_names) -> OpTelemetry:
    """Sum per-shard counters across the mesh (call under shard_map) —
    the distributed layer's one-liner for whole-mesh telemetry."""
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_names), tel)


def host_telemetry(tel: OpTelemetry) -> OpTelemetry:
    """Materialize a (possibly async) telemetry record on the host."""
    return OpTelemetry(*[np.int64(np.asarray(v)) for v in tel])
