"""Span tracing — a low-overhead host-side tracer with Chrome export.

Records nestable spans (``with tracer.span("wave.dispatch"): ...``) and
instant events (``tracer.instant("maintenance.deferred")``) against a
single `perf_counter` epoch, then exports the Chrome trace-event JSON
format (the ``{"traceEvents": [...]}`` object form) loadable in Perfetto
or ``chrome://tracing``.

Span taxonomy wired by the serving stack (see DESIGN.md §Observability):

  engine.submit      request admission into the splice queue
  wave.splice        staging-buffer fill from queued requests
  wave.dispatch      device launch of one wave (snapshot → fn → offer)
  wave.reap          flight retirement (block_until_ready + deliver)
  request            one request's full queue-wait + service lifetime
                     (emitted at completion from the engine's stamps)
  maintenance.run    one scheduler step (fn + block_until_ready)
  maintenance.deferred   instant: on_wave skipped — cost EWMA over slack
  publisher.publish  instant: staged table promoted to serving
  publisher.offer    instant: new table version offered to the publisher
  delta.export / delta.ingest   checkpoint delta streaming

Every consumer stores ``self.tracer = as_tracer(tracer)`` so call sites
are unconditional; the default `NOOP_TRACER` makes each a no-op attribute
call (no branches at the call sites, no events retained).
"""

from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from typing import Optional


class Tracer:
    """Collects trace events in memory; thread-safe (the serving engine
    dispatches and reaps from the caller thread but maintenance may run
    from a helper).  Timestamps are microseconds since the tracer's
    creation — one shared epoch so spans from all components align."""

    def __init__(self):
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self.events: list[dict] = []

    # -- clock ---------------------------------------------------------------

    def now(self) -> float:
        """Seconds since the tracer epoch (pair with `complete`)."""
        return time.perf_counter() - self._t0

    def _us(self, t_s: float) -> float:
        return round(t_s * 1e6, 3)

    # -- recording -----------------------------------------------------------

    @contextmanager
    def span(self, name: str, **args):
        """Record a complete span (ph="X") around the with-body."""
        t_start = self.now()
        try:
            yield self
        finally:
            self.complete(name, t_start, self.now(), **args)

    def complete(self, name: str, t_start: float, t_end: float, **args):
        """Record a span from explicit epoch-relative stamps (seconds) —
        for lifetimes that straddle call boundaries, e.g. a request's
        submit→done window stamped by the engine."""
        ev = {
            "name": name,
            "ph": "X",
            "ts": self._us(t_start),
            "dur": self._us(max(t_end - t_start, 0.0)),
            "pid": 0,
            "tid": 0,
        }
        if args:
            ev["args"] = args
        with self._lock:
            self.events.append(ev)

    def complete_abs(self, name: str, t_start: float, t_end: float, **args):
        """`complete` from raw `time.perf_counter()` stamps — for code
        that stamped lifetimes before a tracer was in the picture (the
        engine's per-request t_submit/t_done)."""
        self.complete(name, t_start - self._t0, t_end - self._t0, **args)

    def instant(self, name: str, **args):
        """Record an instant event (ph="i", thread-scoped)."""
        ev = {
            "name": name,
            "ph": "i",
            "s": "t",
            "ts": self._us(self.now()),
            "pid": 0,
            "tid": 0,
        }
        if args:
            ev["args"] = args
        with self._lock:
            self.events.append(ev)

    # -- export --------------------------------------------------------------

    def to_chrome(self) -> dict:
        """The Chrome trace-event object form (Perfetto-loadable)."""
        with self._lock:
            return {
                "traceEvents": list(self.events),
                "displayTimeUnit": "ms",
                "otherData": {"tracer": "hkv-obs"},
            }

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=None, separators=(",", ":"))
            f.write("\n")

    def __len__(self) -> int:
        with self._lock:
            return len(self.events)


class NoopTracer:
    """Absorbs the full `Tracer` surface at near-zero cost — the default
    when no tracer is wired, so instrumented code never branches."""

    events: tuple = ()

    def now(self) -> float:
        return 0.0

    @contextmanager
    def span(self, name: str, **args):
        yield self

    def complete(self, name: str, t_start: float, t_end: float, **args):
        pass

    def complete_abs(self, name: str, t_start: float, t_end: float, **args):
        pass

    def instant(self, name: str, **args):
        pass

    def to_chrome(self) -> dict:
        return {"traceEvents": []}

    def save(self, path) -> None:
        raise RuntimeError("NoopTracer records nothing; wire a Tracer first")

    def __len__(self) -> int:
        return 0

    def __bool__(self) -> bool:  # `if self.tracer:` → "is tracing live?"
        return False


NOOP_TRACER = NoopTracer()


def as_tracer(tracer: Optional[Tracer]):
    """Normalize an optional tracer argument: None → the shared noop."""
    return NOOP_TRACER if tracer is None else tracer
