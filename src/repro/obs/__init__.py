"""hkv-obs — the observability subsystem (ISSUE 10).

Three parts, one layering rule (obs imports core, never the reverse —
`repro.core.ops` reaches back only through a deferred import inside the
`telemetry is not None` branch, so the default path stays import-free):

  telemetry   `OpTelemetry` + `TelemetrySink`: device-computed per-op
              counters (buckets probed, digest-prefilter pass counts,
              dual-bucket second probes, hits/misses, eviction vs
              admission-rejection splits, tier motion) threaded through
              the core op families via an optional `telemetry=` channel.
              Contract: op results are bit-identical with telemetry on or
              off, and `telemetry=None` (the default) adds zero kernel
              launches and zero jaxpr growth.
  trace       host-side span tracer (nestable spans + instant events)
              exporting Chrome trace-event JSON loadable in Perfetto —
              wired through the serving wave lifecycle, the maintenance
              scheduler, and the publisher.
  metrics     one `MetricsRegistry` aggregating `EngineMetrics`,
              `MaintenanceTotals`, `TableStats`, and accumulated
              `OpTelemetry` into a single snapshot with Prometheus
              text-format exposition and a bench-trajectory JSON dump.
"""

from repro.obs.metrics import MetricsRegistry
from repro.obs.telemetry import OpTelemetry, TelemetrySink
from repro.obs.trace import NOOP_TRACER, NoopTracer, Tracer, as_tracer

__all__ = [
    "MetricsRegistry",
    "OpTelemetry",
    "TelemetrySink",
    "Tracer",
    "NoopTracer",
    "NOOP_TRACER",
    "as_tracer",
]
