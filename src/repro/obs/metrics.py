"""Metrics registry — one snapshot over every hkv measurement surface.

`MetricsRegistry` aggregates the subsystem summaries that already exist
(`EngineMetrics` host timers, `MaintenanceTotals`, `TableStats` /
`tier_stats()`) together with accumulated device `OpTelemetry` into a
single flat gauge namespace, then exports it two ways:

  * `prometheus()` — text exposition format (`# HELP`/`# TYPE`/value
    lines) for scraping or a one-shot `--metrics-out` dump;
  * `to_json()` / `snapshot()` — a flat dict `benchmarks/run.py` folds
    into the `BENCH_*.json` bench-trajectory schema via `Csv` rows.

Gauge names follow the Prometheus convention `hkv_<subsystem>_<metric>`:

  hkv_engine_*        waves, keys, hit_rate, kv_per_s, SLO percentiles
  hkv_maintenance_*   runs, expired, demoted, dropped, deferred, time_s
  hkv_table_*         size, capacity, load_factor (hkv_hot_* / hkv_cold_*
                      for the tier hierarchy's per-tier stats)
  hkv_op_<op>_<ctr>   accumulated OpTelemetry per op family, plus the
                      derived hkv_op_<op>_probes_per_query etc. rates

Everything is pull: observers hand their summary objects in, the
registry flattens to floats at observe-time (blocking on device values),
and exports read the gauge dict.  No background threads, no sampling.
"""

from __future__ import annotations

import json
import numpy as np

from repro.obs.telemetry import OpTelemetry, TelemetrySink


def _scalar(v) -> float:
    """Best-effort float of a host/device scalar."""
    return float(np.asarray(v))


class MetricsRegistry:
    """A flat gauge registry with subsystem-aware observers."""

    def __init__(self, namespace: str = "hkv"):
        self.namespace = namespace
        self._gauges: dict[str, float] = {}
        self._help: dict[str, str] = {}

    # -- primitive surface ---------------------------------------------------

    def set(self, name: str, value, help: str = "") -> None:
        self._gauges[name] = _scalar(value)
        if help:
            self._help[name] = help

    def inc(self, name: str, value=1.0) -> None:
        self._gauges[name] = self._gauges.get(name, 0.0) + _scalar(value)

    def get(self, name: str) -> float:
        return self._gauges[name]

    # -- subsystem observers -------------------------------------------------

    def observe_engine(self, metrics) -> None:
        """Fold an `EngineMetrics` snapshot (NamedTuple) into gauges."""
        p = f"{self.namespace}_engine_"
        for field, value in metrics._asdict().items():
            self.set(p + field, value)
        self._help[p + "kv_per_s"] = "serving throughput, keys per second"
        self._help[p + "hit_rate"] = "fraction of served keys found resident"

    def observe_maintenance(self, totals) -> None:
        """Fold `MaintenanceTotals` (NamedTuple) into gauges."""
        p = f"{self.namespace}_maintenance_"
        for field, value in totals._asdict().items():
            self.set(p + field, value)
        self._help[p + "deferred"] = (
            "maintenance steps skipped: between-wave slack already spent")

    def observe_table(self, stats, *, tier: str = "table") -> None:
        """Fold a `TableStats` into gauges; `tier` prefixes the name
        ("table" for a flat table, "hot"/"cold" per tier)."""
        p = f"{self.namespace}_{tier}_"
        self.set(p + "size", stats.size, "live entries")
        self.set(p + "capacity", stats.capacity)
        self.set(p + "load_factor", stats.load_factor,
                 "live entries / slots (lambda)")
        hist = np.asarray(stats.occupancy_hist)
        full = int(hist[-1]) if hist.size else 0
        self.set(p + "full_buckets", full,
                 "buckets at slot capacity (reactive-eviction pressure)")

    def observe_telemetry(self, sink: TelemetrySink) -> None:
        """Fold a sink's accumulated per-op `OpTelemetry` into gauges,
        including the derived rates the paper's claims anchor to."""
        for op, tel in sink.by_op.items():
            self.observe_op(op, tel, calls=sink.calls.get(op, 0))

    def observe_op(self, op: str, tel: OpTelemetry, *, calls: int = 0) -> None:
        p = f"{self.namespace}_op_{op}_"
        for counter, value in tel.to_dict().items():
            self.set(p + counter, value)
        for rate, value in tel.rates().items():
            self.set(p + rate, value)
        if calls:
            self.set(p + "calls", calls)
        self._help[p + "probes_per_query"] = (
            "bucket rows fetched per valid key (flat across load factor)")
        self._help[p + "digest_pass_rate"] = (
            "probed slots passing the 8-bit digest prefilter")

    # -- exports -------------------------------------------------------------

    def snapshot(self) -> dict:
        """The flat {gauge: float} view (sorted, JSON-ready)."""
        return dict(sorted(self._gauges.items()))

    def prometheus(self) -> str:
        """Prometheus text exposition format (all gauges)."""
        lines = []
        for name, value in sorted(self._gauges.items()):
            if name in self._help:
                lines.append(f"# HELP {name} {self._help[name]}")
            lines.append(f"# TYPE {name} gauge")
            if value == int(value) and abs(value) < 1e15:
                lines.append(f"{name} {int(value)}")
            else:
                lines.append(f"{name} {value:.6g}")
        return "\n".join(lines) + "\n"

    def to_json(self, **extra) -> str:
        """JSON dump of the snapshot (+ caller-supplied context fields)."""
        doc = {"schema": "hkv-metrics/v1", "gauges": self.snapshot()}
        doc.update(extra)
        return json.dumps(doc, indent=2, sort_keys=True)

    def save(self, path, *, format: str = "prometheus") -> None:
        text = self.prometheus() if format == "prometheus" else self.to_json()
        with open(path, "w") as f:
            f.write(text)

    def __len__(self) -> int:
        return len(self._gauges)
