"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
      --steps 20 --backend hkv --ckpt-dir runs/ckpt

On the dev container this runs the REDUCED config on a small host mesh
(--smoke); on a TPU slice the same script runs the full config on the
production mesh (jax.distributed.initialize is invoked when the
environment advertises multi-host).
"""

from __future__ import annotations

import argparse
import os

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--backend", choices=("dense", "hkv"), default="dense")
    ap.add_argument("--hkv-hot-capacity", type=int, default=None,
                    help="run the HKV table as a two-tier hierarchy: this "
                    "many HBM hot slots in front of the (host-capacity) "
                    "cold table — DESIGN.md §2.5; requires --backend hkv")
    ap.add_argument("--optimizer", choices=("adamw", "adamw8bit", "adafactor", "sgdm"),
                    default="adamw")
    ap.add_argument("--ckpt-dir", default="runs/ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if os.environ.get("REPRO_MULTIHOST"):
        import jax

        jax.distributed.initialize()

    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.data import DataCursor, HostPrefetcher, TokenStream
    from repro.distributed.table_sharding import ShardedHKVTable
    from repro.embedding.dynamic import HKVEmbedding
    from repro.embedding.sparse_opt import SparseOptimizer
    from repro.launch.mesh import make_dev_mesh
    from repro.optim import adafactor, adamw, adamw8bit, sgdm
    from repro.train.driver import TrainDriver
    from repro.train.step import StepBuilder

    arch = get_arch(args.arch)
    lm = arch.smoke if args.smoke else arch.lm
    if args.backend == "hkv":
        lm = dataclasses.replace(lm, embedding_backend="hkv", tied_head=False)
    from repro.models.lm import CompositeLM

    model = CompositeLM(lm)
    mesh = make_dev_mesh(args.data_mesh, args.model_mesh)
    opt = {"adamw": adamw, "adamw8bit": adamw8bit, "adafactor": adafactor,
           "sgdm": sgdm}[args.optimizer]()

    params = model.init(jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)

    stream = TokenStream(seed=args.seed, batch=args.batch, seq=args.seq,
                         vocab=lm.vocab, alpha=1.0)

    if args.backend == "hkv":
        table = ShardedHKVTable.create(
            mesh,
            HKVEmbedding(
                capacity=max(256, (2 * lm.vocab // 128) * 128),
                dim=lm.d_model,
                optimizer=SparseOptimizer("rowwise_adagrad", lr=0.05),
                # two-tier hierarchy per shard: hot set in HBM, tail in
                # the host-capacity cold tier (DESIGN.md §2.5)
                hot_capacity=args.hkv_hot_capacity,
            ),
        )
        builder = StepBuilder(model, opt)

        @jax.jit
        def step_fn(state, batch):
            params, opt_state, table = state
            params, opt_state, table, metrics = builder.train_step_hkv(
                params, opt_state, table, batch
            )
            return (params, opt_state, table), metrics

        state = (params, opt_state, table)
    else:
        builder = StepBuilder(model, opt)

        @jax.jit
        def step_fn(state, batch):
            params, opt_state = state
            params, opt_state, metrics = builder.train_step(params, opt_state, batch)
            return (params, opt_state), metrics

        state = (params, opt_state)

    def batch_fn(step):
        toks, labels = stream.batch_at(step)
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}

    driver = TrainDriver(
        step_fn=step_fn,
        batch_fn=batch_fn,
        state=state,
        ckpt_dir=args.ckpt_dir,
        cursor=DataCursor(seed=args.seed, step=0),
        checkpoint_every=args.checkpoint_every,
    )
    hist = driver.run(args.steps)
    losses = hist["loss"]
    print(f"[train] {args.arch} backend={args.backend}: "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps "
          f"({hist['restarts']} restarts)")
    return hist


if __name__ == "__main__":
    main()
