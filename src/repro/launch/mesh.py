"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax use).

Production layout (TPU v5e pods):
  single-pod:  (data=16, model=16)            = 256 chips
  multi-pod:   (pod=2, data=16, model=16)     = 512 chips
The "pod" axis carries data parallelism across pods by default (gradient
all-reduce crosses DCN); pipeline parallelism over "pod" is available via
distributed.pipeline for bandwidth-constrained inter-pod links.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axes carrying data parallelism (pod folds into DP when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
