import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract the roofline terms from the compiled artifact.

The two lines above run before ANY other import — jax locks the device
count at first init, and the dry-run (and only the dry-run) needs 512
placeholder host devices to build the (2, 16, 16) multi-pod mesh.

Per cell this script:
  1. builds the full-size model and ShapeDtypeStruct inputs (no allocation),
  2. jits the real step (train_step with optimizer / prefill / decode) with
     NamedShardings from distributed.sharding,
  3. .lower().compile() — success proves the sharding config is coherent
     (no sharding mismatch, no unsupported collective),
  4. records compiled.memory_analysis() (fits-per-device evidence),
     compiled.cost_analysis() (FLOPs / bytes for §Roofline), and the
     collective inventory parsed from compiled.as_text() (op kind, result
     bytes, group size) for the collective roofline term.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh multi --out runs/dryrun
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --backend hkv
"""

import argparse
import json
import re
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, get_arch
from repro.distributed import sharding as shard_rules
from repro.distributed.table_sharding import ShardedHKVEmbedding, ShardedHKVTable
from repro.embedding.dynamic import HKVEmbedding
from repro.embedding.sparse_opt import SparseOptimizer
from repro.launch.mesh import make_production_mesh
from repro.optim import adafactor, adamw
from repro.train.step import StepBuilder

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def parse_collectives(hlo_text: str):
    """[(kind, result_bytes, group_size)] from the partitioned HLO."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        _, dt, dims, kind = m.groups()
        if "-done" in line.split("=")[0]:
            continue
        size = _DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                size *= int(d)
        g = _GROUPS_RE.search(line)
        if g:
            group_size = int(g.group(2))
        else:
            gb = _GROUPS_BRACE_RE.search(line)
            group_size = len(gb.group(1).split(",")) if gb else 1
        out.append({"kind": kind, "result_bytes": size, "group_size": group_size})
    return out


def collective_wire_bytes(colls) -> float:
    """Per-device bytes on the wire, ring-algorithm accounting:
    all-reduce: 2 x N x (g-1)/g; all-gather (N = result): N x (g-1)/g;
    reduce-scatter (N = input ~ result x g): N x (g-1)/g; all-to-all:
    N x (g-1)/g; collective-permute: N."""
    total = 0.0
    for c in colls:
        n, g = c["result_bytes"], max(c["group_size"], 1)
        if g == 1:
            continue
        f = (g - 1) / g
        if c["kind"] == "all-reduce":
            total += 2 * n * f
        elif c["kind"] == "collective-permute":
            total += n
        else:
            total += n * f
    return total


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _batch_specs(arch, shape, mesh, d_model):
    """ShapeDtypeStructs + NamedShardings for one training/prefill batch."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    b, s = shape.global_batch, shape.seq
    bspec = P(dp, None) if b % dp_size == 0 else P(None, None)
    batch = {
        "tokens": _sds((b, s), jnp.int32),
        "labels": _sds((b, s), jnp.int32),
    }
    specs = {
        "tokens": NamedSharding(mesh, bspec),
        "labels": NamedSharding(mesh, bspec),
    }
    if arch.lm.frontend == "vision":
        sv = arch.vision_tokens
        batch["frontend_embeds"] = _sds((b, sv, d_model), jnp.bfloat16)
        specs["frontend_embeds"] = NamedSharding(
            mesh, P(bspec[0], None, None)
        )
        batch["mrope_positions"] = _sds((3, b, s), jnp.int32)
        specs["mrope_positions"] = NamedSharding(mesh, P(None, bspec[0], None))
    return batch, specs


def _opt_for(arch_name: str):
    # llama4's 395 B params need factored moments to fit HBM (see DESIGN.md)
    if arch_name.startswith("llama4"):
        return adafactor(), "adafactor"
    return adamw(), "adamw"


def _opt_specs(opt_name, opt_state_shape, pspecs, mesh):
    if opt_name == "adamw":
        specs = {
            "mu": pspecs, "nu": pspecs,
            "count": P(),
        }
    else:  # adafactor: factored moments are small; replicate
        specs = jax.tree.map(lambda _: P(), opt_state_shape)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def build_and_compile(arch_name: str, shape_name: str, mesh_kind: str,
                      backend: str = "dense", scan_train: bool = False):
    arch = get_arch(arch_name)
    shape = arch.shape(shape_name)
    if shape.skip:
        return {"arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
                "backend": backend, "skipped": shape.skip}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    model = arch.model()
    if scan_train:
        # fast mode for the multi-pod coherence pass: scan-over-layers for
        # EVERY cell kind compiles ~5-10x faster and exercises the identical
        # sharding decisions; FLOP/memory fidelity lives in the single-pod
        # (unrolled) artifacts that feed §Roofline.
        import dataclasses as _dc

        from repro.models.lm import CompositeLM as _CLM

        model = _CLM(_dc.replace(arch.lm, scan_layers=True))
    elif shape.kind == "train" and arch.family == "moe":
        # MoE train graphs are compile-time-bound when unrolled on this
        # 1-core dev container; scan-over-layers keeps the dry-run cheap.
        # Caveat recorded in EXPERIMENTS.md §Dry-run: scanned-loop cells
        # under-report FLOPs (XLA counts loop bodies once) and over-report
        # temp memory (scan-linearization stacks flash residuals); the
        # roofline uses analytic FLOPs for these cells.
        import dataclasses as _dc

        from repro.models.lm import CompositeLM as _CLM

        model = _CLM(_dc.replace(arch.lm, scan_layers=True))
    t0 = time.time()

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = shard_rules.param_specs(params_shape)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)

    record = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
        "backend": backend, "kind": shape.kind,
        "devices": int(np.prod(list(mesh.shape.values()))),
        "params": arch.param_count(),
    }

    with mesh:
        if shape.kind == "train":
            opt, opt_name = _opt_for(arch_name)
            opt_shape = jax.eval_shape(opt.init, params_shape)
            osh = _opt_specs(opt_name, opt_shape, pspecs, mesh)
            batch, bsh = _batch_specs(arch, shape, mesh, arch.lm.d_model)
            if backend == "hkv":
                emb = ShardedHKVEmbedding(
                    emb=HKVEmbedding(
                        capacity=_hkv_capacity(arch.lm.vocab),
                        dim=arch.lm.d_model,
                        optimizer=SparseOptimizer("rowwise_adagrad"),
                    ),
                    axis_names=tuple(mesh.axis_names),
                )
                import dataclasses as _dc

                hkv_model = type(model)(_dc.replace(
                    arch.lm, embedding_backend="hkv", tied_head=False))
                hkv_params_shape = jax.eval_shape(
                    hkv_model.init, jax.random.PRNGKey(0))
                pspecs = shard_rules.param_specs(hkv_params_shape)
                psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
                opt_shape = jax.eval_shape(opt.init, hkv_params_shape)
                osh = _opt_specs(opt_name, opt_shape, pspecs, mesh)
                builder = StepBuilder(hkv_model, opt)
                n_shards = record["devices"]
                local = emb.local_embedding(n_shards)
                local_shape = jax.eval_shape(lambda: local.create().state)
                # GLOBAL table ShapeDtypeStructs: local bucket/value planes
                # concatenate over the n_shards table shards; clocks replicate
                table_state_shape = jax.tree.map(
                    lambda a: jax.ShapeDtypeStruct(
                        (a.shape[0] * n_shards,) + a.shape[1:], a.dtype
                    ) if a.ndim >= 1 else a,
                    local_shape,
                )
                # the step threads the handle; shapes/shardings wrap its leaf
                table_shape = ShardedHKVTable(
                    state=table_state_shape, semb=emb, mesh=mesh)
                tsh = ShardedHKVTable(
                    state=jax.tree.map(
                        lambda s: NamedSharding(mesh, s), emb.state_specs()),
                    semb=emb, mesh=mesh)
                fn = jax.jit(
                    builder.train_step_hkv,
                    in_shardings=(psh, osh, tsh, bsh),
                    donate_argnums=(0, 1, 2),
                )
                lowered = fn.lower(hkv_params_shape, opt_shape, table_shape, batch)
            else:
                builder = StepBuilder(model, opt)
                fn = jax.jit(
                    builder.train_step,
                    in_shardings=(psh, osh, bsh),
                    donate_argnums=(0, 1),
                )
                lowered = fn.lower(params_shape, opt_shape, batch)

        elif shape.kind == "prefill":
            batch, bsh = _batch_specs(arch, shape, mesh, arch.lm.d_model)
            extra_keys = [k for k in batch if k not in ("tokens", "labels")]

            def prefill_fn(params, tokens, *extras):
                kw = dict(zip(extra_keys, extras))
                return model.prefill(params, tokens, max_len=shape.seq, **kw)

            fn = jax.jit(
                prefill_fn,
                in_shardings=(psh, bsh["tokens"], *[bsh[k] for k in extra_keys]),
            )
            lowered = fn.lower(
                params_shape, batch["tokens"], *[batch[k] for k in extra_keys]
            )

        else:  # decode
            b = shape.global_batch
            dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
            dp_size = int(np.prod([mesh.shape[a] for a in dp]))
            state_shape = jax.eval_shape(
                lambda: model.init_decode_state(batch=b, max_len=shape.seq)
            )
            kv_div = all(
                seg.block.kind != "attn" or seg.block.kv_heads % mesh.shape["model"] == 0
                for seg in (tuple(arch.lm.prelude) + tuple(arch.lm.segments))
            )
            sspecs = shard_rules.decode_state_specs(mesh, state_shape, kv_div)
            if b % dp_size != 0:  # long_500k batch=1: replicate batch dim
                sspecs = jax.tree.map(
                    lambda s: P(*(None if a == "data" else a for a in s)), sspecs,
                    is_leaf=lambda x: isinstance(x, P),
                )
            ssh = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                               is_leaf=lambda x: isinstance(x, P))
            tok_spec = NamedSharding(mesh, P(dp) if b % dp_size == 0 else P(None))
            toks = _sds((b,), jnp.int32)

            fn = jax.jit(
                model.decode_step,
                in_shardings=(psh, tok_spec, ssh),
                donate_argnums=(2,),
            )
            lowered = fn.lower(params_shape, toks, state_shape)

        compiled = lowered.compile()

    record["compile_s"] = round(time.time() - t0, 1)
    ma = compiled.memory_analysis()
    record["memory"] = {
        "argument_bytes_per_device": int(ma.argument_size_in_bytes),
        "output_bytes_per_device": int(ma.output_size_in_bytes),
        "temp_bytes_per_device": int(ma.temp_size_in_bytes),
        "alias_bytes_per_device": int(ma.alias_size_in_bytes),
        "peak_estimate_per_device": int(
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes
        ),
    }
    ca = compiled.cost_analysis() or {}
    record["cost"] = {
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_accessed_per_device": float(ca.get("bytes accessed", 0.0)),
    }
    colls = parse_collectives(compiled.as_text())
    agg = {}
    for c in colls:
        k = c["kind"]
        agg.setdefault(k, {"count": 0, "result_bytes": 0})
        agg[k]["count"] += 1
        agg[k]["result_bytes"] += c["result_bytes"]
    record["collectives"] = agg
    record["collective_wire_bytes_per_device"] = collective_wire_bytes(colls)
    return record


def _hkv_capacity(vocab: int) -> int:
    cap = max(1, (2 * vocab) // 128) * 128  # 2x vocab working-set headroom
    return cap


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--backend", choices=("dense", "hkv"), default="dense")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--skip-existing", action="store_true",
                    help="resume a partial grid: skip cells with artifacts")
    ap.add_argument("--scan-train", action="store_true",
                    help="scan-over-layers for train cells (fast sharding-"
                         "coherence pass; see build_and_compile)")
    args = ap.parse_args()

    cells = []
    if args.all:
        for name in ARCH_NAMES:
            arch = get_arch(name)
            for sh in arch.shapes:
                cells.append((name, sh.name))
    else:
        assert args.arch and args.shape
        cells.append((args.arch, args.shape))

    os.makedirs(os.path.join(args.out, args.mesh), exist_ok=True)
    for arch_name, shape_name in cells:
        tag = f"{arch_name}__{shape_name}__{args.backend}"
        path = os.path.join(args.out, args.mesh, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            with open(path) as f:
                prev = json.load(f)
            if "error" not in prev:
                print(f"=== {tag} on {args.mesh} === (cached)", flush=True)
                continue
        print(f"=== {tag} on {args.mesh} ===", flush=True)
        try:
            rec = build_and_compile(arch_name, shape_name, args.mesh,
                                    args.backend, scan_train=args.scan_train)
        except Exception as e:  # noqa: BLE001 — recorded, not fatal for --all
            rec = {"arch": arch_name, "shape": shape_name, "mesh": args.mesh,
                   "backend": args.backend, "error": f"{type(e).__name__}: {e}"}
            print(f"    FAILED: {rec['error']}", flush=True)
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        if "error" not in rec and "skipped" not in rec:
            print(
                f"    ok compile={rec['compile_s']}s "
                f"flops/dev={rec['cost']['flops_per_device']:.3e} "
                f"peak_mem/dev={rec['memory']['peak_estimate_per_device']/2**30:.2f}GiB "
                f"coll_wire/dev={rec['collective_wire_bytes_per_device']/2**20:.1f}MiB",
                flush=True,
            )
        elif "skipped" in rec:
            print(f"    SKIP: {rec['skipped']}", flush=True)


if __name__ == "__main__":
    main()
