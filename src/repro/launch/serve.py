"""Serving launcher: batched prefill + decode loop over a small model.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --batch 4 --prompt-len 32 --decode-steps 16
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch

    arch = get_arch(args.arch)
    model = arch.model(smoke=args.smoke)
    lm = arch.smoke if args.smoke else arch.lm
    params = model.init(jax.random.PRNGKey(args.seed))

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, lm.vocab, size=(args.batch, args.prompt_len)), jnp.int32
    )
    max_len = args.prompt_len + args.decode_steps

    prefill = jax.jit(lambda p, t: model.prefill(p, t, max_len=max_len))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, state = prefill(params, prompts)
    toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [toks]
    for _ in range(args.decode_steps - 1):
        logits, state = decode(params, toks, state)
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    gen = np.stack([np.asarray(t) for t in out], axis=1)
    print(f"[serve] {args.arch}: generated {gen.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.decode_steps / dt:.1f} tok/s)")
    print("first sequence:", gen[0].tolist())
    return gen


if __name__ == "__main__":
    main()
