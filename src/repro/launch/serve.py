"""Serving launcher.

Default mode drives the paper's title scenario: an `OnlineEmbeddingEngine`
serving zipfian embedding lookups from a `TieredHKVTable` behind a
`TablePublisher`, with an `OnlineTrainer` interleaving streaming updates
(the §3.5 reader/updater/inserter triple under live eviction):

  PYTHONPATH=src python -m repro.launch.serve --smoke \
      --waves 16 --wave-size 256 --miss-policy admit

`--arrival` picks the request-size process (steady | burst | diurnal)
and `--admission continuous` turns on continuous-batch admission
(per-lane splice + double-buffered staging); the summary line then
reports the per-request queue-wait / service / total p50-p99 split:

  PYTHONPATH=src python -m repro.launch.serve --smoke \
      --arrival burst --admission continuous

`--mode lm` keeps the LM prefill+decode loop over a small model:

  PYTHONPATH=src python -m repro.launch.serve --mode lm --arch qwen2-0.5b \
      --smoke --batch 4 --prompt-len 32 --decode-steps 16
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("embedding", "lm"), default="embedding")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    # embedding mode
    ap.add_argument("--hot-capacity", type=int, default=16 * 128)
    ap.add_argument("--cold-capacity", type=int, default=128 * 128)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--wave-size", type=int, default=1024)
    ap.add_argument("--waves", type=int, default=64)
    ap.add_argument("--miss-policy", choices=("readonly", "admit"),
                    default="admit")
    ap.add_argument("--no-promote", action="store_true",
                    help="readonly waves stay pure readers (no tiered "
                         "miss-path promotion)")
    ap.add_argument("--zipf-alpha", type=float, default=1.05)
    ap.add_argument("--maintain", action="store_true",
                    help="run the MaintenanceScheduler between waves "
                         "(watermark rebalance; DESIGN.md §Maintenance)")
    ap.add_argument("--sweep-budget", type=int, default=512,
                    help="max structural moves per maintenance step")
    ap.add_argument("--maintain-every", type=int, default=1,
                    help="waves between maintenance steps")
    ap.add_argument("--update-read-ratio", type=float, default=0.25,
                    help="trainer steps per served wave")
    ap.add_argument("--arrival", choices=("steady", "burst", "diurnal"),
                    default="steady",
                    help="request-size process per tick (data.synthetic "
                         "arrival generators); steady submits exactly one "
                         "wave-sized request per tick")
    ap.add_argument("--admission", choices=("wave", "continuous"),
                    default="wave",
                    help="wave-granular admission or continuous batching "
                         "(splice into partially-drained staging, "
                         "double-buffered dispatch)")
    ap.add_argument("--host-budget-ms", type=float, default=None,
                    help="between-wave host slack budget (ms) that "
                         "staging and maintenance compete for; default "
                         "cadence-only maintenance")
    # observability (repro.obs; DESIGN.md §Observability)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON (Perfetto-"
                         "loadable) of the serve run's span timeline")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the end-of-run MetricsRegistry snapshot "
                         "in Prometheus text exposition format")
    # lm mode
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    args = ap.parse_args()
    if args.mode == "lm":
        return _lm_main(args)
    return _embedding_main(args)


def _embedding_main(args):
    import jax.numpy as jnp
    import numpy as np

    from repro.core import TieredHKVTable
    from repro.data import arrival_sizes, zipf_keys
    from repro.serving import (EmbeddingRequest, OnlineEmbeddingEngine,
                               OnlineTrainer, TablePublisher)

    if args.smoke:
        args.hot_capacity = min(args.hot_capacity, 4 * 128)
        args.cold_capacity = min(args.cold_capacity, 16 * 128)
        args.wave_size = min(args.wave_size, 256)
        args.waves = min(args.waves, 12)

    tracer = None
    if args.trace_out:
        from repro.obs import Tracer

        tracer = Tracer()

    table = TieredHKVTable.create(
        hot_capacity=args.hot_capacity, cold_capacity=args.cold_capacity,
        dim=args.dim)
    pub = TablePublisher(table, tracer=tracer)
    trainer = OnlineTrainer(publisher=pub, publish_every=1)
    sched = None
    if args.maintain:
        from repro.maintenance import MaintenancePolicy, MaintenanceScheduler

        sched = MaintenanceScheduler(MaintenancePolicy(
            every_waves=args.maintain_every,
            sweep_budget=args.sweep_budget), tracer=tracer)
    eng = OnlineEmbeddingEngine(
        pub, wave_size=args.wave_size, miss_policy=args.miss_policy,
        promote=not args.no_promote, scheduler=sched,
        admission=args.admission,
        host_budget_s=(args.host_budget_ms / 1e3
                       if args.host_budget_ms is not None else None),
        tracer=tracer)

    serve_rng = np.random.default_rng(args.seed)
    train_rng = np.random.default_rng(args.seed + 1)
    key_space = 2 * args.cold_capacity
    grads = jnp.ones((args.wave_size, args.dim), jnp.float32)

    # per-tick arrivals: 'steady' keeps the legacy one-wave-per-tick
    # load; 'burst'/'diurnal' modulate the offered key count, so the
    # queue genuinely builds and drains (the SLO split below reports it)
    sizes = arrival_sizes(args.arrival, np.random.default_rng(args.seed + 2),
                          args.waves, args.wave_size,
                          **({"base_load": 1.0}
                             if args.arrival == "steady" else {}))
    due = 0.0
    for i, sz in enumerate(sizes):
        eng.submit(EmbeddingRequest(
            rid=i,
            keys=zipf_keys(serve_rng, int(sz), args.zipf_alpha, key_space)))
        r = eng.step()
        due += args.update_read_ratio
        while due >= 1.0:
            trainer.train_step(
                zipf_keys(train_rng, args.wave_size, args.zipf_alpha,
                          key_space), grads)
            due -= 1.0
        if r is not None and (i + 1) % max(args.waves // 4, 1) == 0:
            print(f"[serve] wave {i+1:4d}: hit={r.hit_rate*100:5.1f}% "
                  f"kv/s={r.kv_per_s/1e3:.1f}k v{r.table_version}")
    eng.run_until_drained()
    m = eng.metrics()
    print(f"[serve] {m.waves} waves, {m.keys} keys: hit={m.hit_rate*100:.1f}% "
          f"hot={m.hot_rate*100:.1f}% kv/s={m.kv_per_s/1e3:.1f}k "
          f"p50={m.p50_latency_s*1e3:.1f}ms p99={m.p99_latency_s*1e3:.1f}ms "
          f"published={pub.published} offered={pub.offered}")
    print(f"[serve] SLO ({args.admission} admission, {args.arrival} "
          f"arrivals): {m.requests} requests, "
          f"queue-wait p50={m.p50_queue_wait_s*1e3:.1f}ms "
          f"p99={m.p99_queue_wait_s*1e3:.1f}ms | "
          f"service p50={m.p50_service_s*1e3:.1f}ms "
          f"p99={m.p99_service_s*1e3:.1f}ms | "
          f"total p50={m.p50_total_s*1e3:.1f}ms "
          f"p99={m.p99_total_s*1e3:.1f}ms")
    if sched is not None:
        t = sched.totals
        print(f"[serve] maintenance: {t.runs} steps, demoted={t.demoted} "
              f"dropped={t.dropped} deferred={t.deferred} "
              f"time={t.time_s*1e3:.0f}ms; "
              f"reactive demotions/wave={m.demotions_per_wave:.1f}")
    # end-of-run table occupancy (TableStats, the state half of the
    # observability story; the wave counters above are the runtime half)
    hot_stats, cold_stats = pub.table.tier_stats()
    print(f"[serve] table: hot {hot_stats.size}/{hot_stats.capacity} "
          f"(lf={hot_stats.load_factor:.2f}) | "
          f"cold {cold_stats.size}/{cold_stats.capacity} "
          f"(lf={cold_stats.load_factor:.2f})")
    if args.trace_out or args.metrics_out:
        from repro.obs import MetricsRegistry

        reg = MetricsRegistry()
        reg.observe_engine(m)
        if sched is not None:
            reg.observe_maintenance(sched.totals)
        reg.observe_table(hot_stats, tier="hot")
        reg.observe_table(cold_stats, tier="cold")
        if args.metrics_out:
            reg.save(args.metrics_out, format="prometheus")
            print(f"[serve] metrics snapshot ({len(reg)} gauges) -> "
                  f"{args.metrics_out}")
        if args.trace_out:
            tracer.save(args.trace_out)
            print(f"[serve] trace ({len(tracer)} events) -> "
                  f"{args.trace_out}")
    return m


def _lm_main(args):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch

    arch = get_arch(args.arch)
    model = arch.model(smoke=args.smoke)
    lm = arch.smoke if args.smoke else arch.lm
    params = model.init(jax.random.PRNGKey(args.seed))

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, lm.vocab, size=(args.batch, args.prompt_len)), jnp.int32
    )
    max_len = args.prompt_len + args.decode_steps

    prefill = jax.jit(lambda p, t: model.prefill(p, t, max_len=max_len))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, state = prefill(params, prompts)
    toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [toks]
    for _ in range(args.decode_steps - 1):
        logits, state = decode(params, toks, state)
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    gen = np.stack([np.asarray(t) for t in out], axis=1)
    print(f"[serve] {args.arch}: generated {gen.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.decode_steps / dt:.1f} tok/s)")
    print("first sequence:", gen[0].tolist())
    return gen


if __name__ == "__main__":
    main()
