"""Checkpointing: atomic, async, reshard-on-restore.

Layout: <dir>/step_<N>/ holding one .npy per leaf plus a manifest.json with
the treedef, dtypes and the data cursor.  Writes go to a tmp dir that is
os.rename()'d into place — a crashed writer never corrupts the latest
checkpoint (atomic-rename recovery contract).  `save_async` runs the
serialization on a background thread so the device stays busy; `restore`
device_puts every leaf with the *target* sharding, so a checkpoint taken on
one mesh restores onto any other (elastic restart / re-pod-ing).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree: Any, extra: Optional[dict] = None) -> str:
    """Synchronous atomic checkpoint. Returns the final directory."""
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        "extra": extra or {},
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(path, keep=3)
    return final


_pending: list = []


def save_async(path: str, step: int, tree: Any, extra: Optional[dict] = None):
    """Snapshot to host (blocking only for device->host copy), then write on
    a daemon thread. wait_async() joins outstanding writes."""
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    t = threading.Thread(target=save, args=(path, step, host_tree, extra), daemon=True)
    t.start()
    _pending.append(t)
    return t


def wait_async():
    while _pending:
        _pending.pop().join()


def _table_manifest(table) -> dict:
    """Structural fingerprint of a KVTable handle for restore validation.

    For a `TieredHKVTable` this records BOTH tiers — the pair is saved in
    one step directory behind one atomic rename, so a checkpoint can never
    publish a hot tier without its cold tier (the hierarchy's pairs would
    otherwise silently lose their demoted halves on restore)."""
    from repro.core.tiered import TieredHKVTable

    if isinstance(table, TieredHKVTable):
        return {
            "kind": "TieredHKVTable",
            "hot": _table_manifest(table.hot),
            "cold": _table_manifest(table.cold),
        }
    cfg = getattr(table, "cfg", None)
    out = {"kind": type(table).__name__, "capacity": int(table.capacity),
           "dim": int(table.dim)}
    if cfg is not None:
        out["score_policy"] = cfg.score_policy
        out["value_tier"] = cfg.value_tier
    return out


def save_table(path: str, step: int, table, extra: Optional[dict] = None) -> str:
    """Atomic checkpoint of a KVTable handle (flat `HKVTable` or tiered).

    The handle is a pytree whose leaves are the state arrays (cfg rides in
    the treedef), so both tiers of a `TieredHKVTable` land in ONE step_<N>/
    directory and publish via ONE os.rename — save/restore of the hierarchy
    is all-or-nothing.  The manifest records each tier's shape for
    validation at restore time."""
    extra = dict(extra or {})
    extra["table"] = _table_manifest(table)
    return save(path, step, table, extra=extra)


def restore_table(path: str, step: int, table):
    """Restore a table checkpoint onto `table`'s structure (its cfg/backend
    come from the live handle; leaves come from disk).  Raises if the
    checkpoint's recorded table structure does not match the target —
    restoring a flat checkpoint into a tiered handle (or mismatched tier
    capacities) would silently misassign value planes otherwise."""
    restored, extra = restore(path, step, table)
    want = extra.get("table")
    got = _table_manifest(table)
    if want is not None and want != got:
        raise ValueError(
            f"checkpoint table structure {want} does not match the restore "
            f"target {got}"
        )
    return restored, extra


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(path)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(path: str, step: int, target_tree: Any, shardings: Any = None):
    """Restore onto the structure (and optionally the sharding) of
    `target_tree`. The checkpoint's mesh is irrelevant: leaves are plain
    host arrays re-placed under the target sharding (elastic restart)."""
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    _, treedef = _flatten(target_tree)
    leaves = [
        np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
        for i in range(manifest["num_leaves"])
    ]
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest["extra"]


def _gc(path: str, keep: int):
    steps = sorted(
        d for d in os.listdir(path) if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(path, d), ignore_errors=True)
