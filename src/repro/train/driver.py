"""Fault-tolerant training driver.

The contract for thousands of nodes (DESIGN.md §5):

  * checkpoint/restart — async atomic checkpoints every `checkpoint_every`
    steps carry params, optimizer state, HKV table state AND the data
    cursor; restart resumes the exact batch stream.
  * node failure — any exception inside a step triggers restore-from-latest
    and replay; `max_failures` bounds the retry budget.  (On a real
    multi-host deployment the same path is driven by the coordinator's
    heartbeat failure detector; here the failure signal is the exception.)
  * elastic scaling — restore re-places every leaf under the CURRENT mesh's
    shardings (see checkpoint.restore) and the data cursor re-shards the
    stream to the new DP world size deterministically.
  * straggler mitigation — synchronous steps bound stragglers by
    construction once a step launches; between steps, `step_timeout`
    converts a hung collective into a failure -> restore path instead of an
    indefinite stall (the production analogue is the coordination-service
    barrier timeout).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Optional

from repro.data.pipeline import DataCursor
from repro.train import checkpoint as ckpt


class StepTimeout(Exception):
    pass


@dataclasses.dataclass
class TrainDriver:
    step_fn: Callable               # (state_tuple, batch) -> (state_tuple, metrics)
    batch_fn: Callable              # (step) -> batch
    state: Any                      # (params, opt_state, [table_state])
    ckpt_dir: str
    cursor: DataCursor
    checkpoint_every: int = 100
    max_failures: int = 3
    step_timeout: Optional[float] = None
    shardings: Any = None
    failure_injector: Optional[Callable] = None   # (step) -> None|raise, for tests
    log: Callable = print

    def _run_step(self, step: int):
        batch = self.batch_fn(step)
        if self.step_timeout is None:
            if self.failure_injector is not None:
                self.failure_injector(step)
            self.state, metrics = self.step_fn(self.state, batch)
            return metrics
        result = {}
        err = []

        def target():
            try:
                # injector runs INSIDE the timed context (a simulated
                # straggler must stall the step, not the watchdog)
                if self.failure_injector is not None:
                    self.failure_injector(step)
                result["out"] = self.step_fn(self.state, batch)
            except Exception as e:  # noqa: BLE001 — surfaced below
                err.append(e)

        t = threading.Thread(target=target, daemon=True)
        t.start()
        t.join(self.step_timeout)
        if t.is_alive():
            raise StepTimeout(f"step {step} exceeded {self.step_timeout}s (straggler)")
        if err:
            raise err[0]
        self.state, metrics = result["out"]
        return metrics

    def _checkpoint(self, step: int):
        ckpt.save_async(self.ckpt_dir, step, self.state, extra=self.cursor.to_dict())

    def _restore_latest(self) -> int:
        ckpt.wait_async()  # an in-flight async save must land before we look
        last = ckpt.latest_step(self.ckpt_dir)
        if last is None:
            # no checkpoint yet: restart from the pristine initial state
            self.state = self._initial_state
            self.cursor = DataCursor(seed=self.cursor.seed, step=0)
            self.log("[driver] no checkpoint found; restarting from step 0")
            return 0
        self.state, extra = ckpt.restore(self.ckpt_dir, last, self.state, self.shardings)
        self.cursor = DataCursor.from_dict(extra)
        self.log(f"[driver] restored step {last} (cursor {self.cursor})")
        return last

    def run(self, num_steps: int) -> dict:
        import jax

        # host-side snapshot of the initial state for restore-from-nothing
        self._initial_state = jax.tree.map(lambda x: x, self.state)
        failures = 0
        step = self.cursor.step
        history = {"loss": [], "restarts": 0}
        while step < num_steps:
            try:
                metrics = self._run_step(step)
                step += 1
                self.cursor.step = step
                if "loss" in metrics:
                    history["loss"].append(float(metrics["loss"]))
                if step % self.checkpoint_every == 0 or step == num_steps:
                    self._checkpoint(step)
            except Exception as e:  # noqa: BLE001 — recovery path
                failures += 1
                history["restarts"] += 1
                self.log(f"[driver] step {step} failed ({type(e).__name__}: {e}); "
                         f"recovery {failures}/{self.max_failures}")
                if failures > self.max_failures:
                    raise
                step = self._restore_latest()
        ckpt.wait_async()
        return history
