"""Train-step builders: dense-embedding and HKV-embedding variants.

The HKV step realizes the paper's triple-group schedule inside one step:

  inserter  find_or_insert on the token batch (structural; the only
            serialization point) — via the all-to-all sharded table;
  readers   the forward pass consumes the gathered rows;
  updater   embedding-row gradients apply through the sparse optimizer's
            non-structural assign, which XLA is free to overlap with the
            dense-parameter update (no data dependence between them).

Gradients: global-norm clipped; DP sync is GSPMD-inserted (or int8
error-feedback compressed over the pod axis when `compress_dp`).
"""

from __future__ import annotations

import dataclasses
import functools
import jax
import jax.numpy as jnp

from repro.distributed import sharding as shard_rules
from repro.distributed.table_sharding import ShardedHKVTable
from repro.models.lm import CompositeLM
from repro.optim import Optimizer
from repro.optim.optimizers import apply_updates


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


@dataclasses.dataclass(frozen=True)
class StepBuilder:
    model: CompositeLM
    optimizer: Optimizer
    grad_clip: float = 1.0

    # ------------------------------------------------------------- dense path

    def train_step(self, params, opt_state, batch):
        """batch: tokens, labels (+ frontend_embeds, mrope_positions)."""
        extras = {
            k: batch[k]
            for k in ("frontend_embeds", "mrope_positions")
            if k in batch
        }

        def loss_fn(p):
            loss, aux = self.model.loss(p, batch["tokens"], batch["labels"], **extras)
            return loss, aux

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, self.grad_clip)
        updates, opt_state = self.optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm, **aux}
        return params, opt_state, metrics

    # --------------------------------------------------------------- hkv path

    def train_step_hkv(self, params, opt_state, table: ShardedHKVTable, batch):
        """The HKV step threads a `ShardedHKVTable` handle: mesh + engine
        ride as static pytree aux, so this jits/donates like any state."""
        tokens = batch["tokens"]
        extras = {
            k: batch[k]
            for k in ("frontend_embeds", "mrope_positions")
            if k in batch
        }
        # INSERTER: one structural op per step (admission-controlled)
        table, embeds, overflow = table.lookup(tokens, train=True)

        def loss_fn(p, e):
            loss, aux = self.model.loss(p, None, batch["labels"], embeds=e, **extras)
            return loss, aux

        (loss, aux), (grads, egrads) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True
        )(params, embeds)
        grads, gnorm = clip_by_global_norm(grads, self.grad_clip)
        updates, opt_state = self.optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        # UPDATER: non-structural sparse write-back, overlappable by XLA
        table = table.apply_grads(tokens, egrads)
        metrics = {"loss": loss, "grad_norm": gnorm, "emb_overflow": overflow, **aux}
        return params, opt_state, table, metrics

    # ----------------------------------------------------------------- serve

    def prefill_step(self, params, tokens, max_len: int, **extras):
        return self.model.prefill(params, tokens, max_len, **extras)

    def decode_step(self, params, tokens, state):
        return self.model.decode_step(params, tokens, state)


def make_sharded_train_step(builder: StepBuilder, mesh, params_shape, hkv: bool):
    """jit the step with NamedSharding in/out constraints for `mesh`."""
    from jax.sharding import NamedSharding

    pspecs = shard_rules.param_specs(params_shape)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    bspec = NamedSharding(mesh, shard_rules.batch_spec(mesh))
    if not hkv:
        return jax.jit(
            builder.train_step,
            donate_argnums=(0, 1),
        )
    return jax.jit(builder.train_step_hkv, donate_argnums=(0, 1, 2))
