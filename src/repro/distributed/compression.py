"""Gradient compression for the DP all-reduce, with error feedback.

int8 block-quantized gradient exchange: each shard quantizes its local
gradient against a pmax-shared block scale, the wire carries int8 payloads
(4x fewer bytes than f32 ring all-reduce when exchanged via all_gather at
small DP degree, or int8 reduce-scatter chunks at large degree), and the
quantization residual is fed back into the next step's gradient (error
feedback keeps SGD convergence — Karimireddy et al.-style).

The compile-visible artifact (dry-run §Roofline) is the collective byte
count: compressed_psum's all_gather moves N x world x 1 B vs psum's ring
2 x N x 4 B — the crossover and the DCN-bound pod axis are analyzed in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_BLOCK = 256


def _block_view(x: jax.Array):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % _BLOCK
    return jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK), flat.shape[0]


def compressed_psum(x: jax.Array, axis_name) -> jax.Array:
    """int8 error-free-scale psum substitute (call inside shard_map).

    Scales are agreed via pmax so every shard quantizes against the same
    grid; payload crosses the wire as int8; the sum happens post-gather in
    int32 (exact given world size < 2^24 blocks)."""
    blocks, n = _block_view(x)
    scale = jax.lax.pmax(jnp.max(jnp.abs(blocks), axis=1), axis_name) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    gathered = jax.lax.all_gather(q, axis_name)           # [world, B, 256] int8 wire
    s = jnp.sum(gathered.astype(jnp.int32), axis=0)
    out = (s.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    return out.reshape(x.shape)


def ef_compress_grads(grads, errors, axis_name):
    """Error-feedback wrapper: (grads + carried error) -> compressed psum,
    new error = local residual. Returns (synced_grads, new_errors)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        blocks, n = _block_view(g32)
        scale = jax.lax.pmax(jnp.max(jnp.abs(blocks), axis=1), axis_name) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
        deq = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n].reshape(g.shape)
        new_e = g32 - deq
        gathered = jax.lax.all_gather(q, axis_name)
        s = jnp.sum(gathered.astype(jnp.int32), axis=0)
        world = gathered.shape[0]
        synced = (s.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n].reshape(
            g.shape
        ) / world
        return synced.astype(g.dtype), new_e

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    synced = jax.tree.unflatten(tree, [o[0] for o in out])
    new_err = jax.tree.unflatten(tree, [o[1] for o in out])
    return synced, new_err


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
