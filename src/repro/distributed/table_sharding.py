"""Distributed HKV table: all-to-all key routing over the device mesh.

The paper delegates multi-GPU sharding to application code (§7); this
module IS that application layer, built the way HugeCTR shards
model-parallel embeddings — and it is the piece that makes the multi-pod
dry-run meaningful for the technique:

  * Every shard owns an independent local HKV table of capacity/n_shards
    (its own buckets, digests, scores, values — all core invariants hold
    locally, including cache semantics at local λ=1.0).
  * A key's OWNER shard is a hash of the key (fmix of h2), so hot Zipfian
    keys scatter uniformly across shards.
  * Lookup/ingest: local dedupe -> capacity-bounded all_to_all of keys to
    owners -> owner-side find_or_insert -> all_to_all of value rows back.
    Wire cost per unique token: 8 B of key out, dim x 4 B of row back —
    strictly less than a vocab-parallel all-reduce at model-axis >= 2.
  * Gradients: the same routing in reverse (updater role).  Each unique
    key's grad is summed locally, routed to its single owner, then
    owner-side deduped across sources and applied ONCE via the sparse
    optimizer — no replica divergence, because no replicas exist.
  * Admission/eviction happen owner-side with unchanged semantics.

Skew handling: per-destination capacity = factor x fair share.  Uniques
beyond capacity fall back to deterministic init rows and are counted in
the returned `overflow` metric (they retry next step; a recurring hot key
is admitted on its next occurrence).

Surfaces (DESIGN.md §API layer): `ShardedHKVEmbedding` is the shard_map
engine (raw HKVState in/out — the form shard_map specs want); the
`ShardedHKVTable` handle on top implements the same `KVTable` protocol as
the single-device `HKVTable`, so consumers and benchmarks drive local and
sharded tables through one code path.  Owner-side table traffic inside
the shard bodies goes through `HKVTable.wrap(...)` — this module never
touches the op engine directly.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import ops as ops_mod
from repro.core import u64
from repro.core.api import HKVTable, dedupe_keys, normalize_keys
from repro.core.merge import EvictionStream
from repro.core.ops import ExportResult
from repro.core.tiered import TieredHKVTable, TieredState
from repro.core.u64 import U64
from repro.distributed.sharding import shard_map
from repro.embedding.dynamic import HKVEmbedding


def _obs_tel():
    """Deferred observer import (the telemetry branch only — same
    discipline as `repro.core.ops._obs`)."""
    from repro.obs import telemetry as obs_telemetry

    return obs_telemetry


@dataclasses.dataclass(frozen=True)
class ShardedHKVEmbedding:
    """HKVEmbedding sharded over mesh axes (default: every mesh axis)."""

    emb: HKVEmbedding              # GLOBAL capacity; local = capacity / n_shards
    axis_names: tuple              # mesh axes the table shards over
    capacity_factor: float = 2.0

    def local_embedding(self, n_shards: int) -> HKVEmbedding:
        def shard_cap(c):
            return max(128, (c // n_shards // 128) * 128)

        return dataclasses.replace(
            self.emb, capacity=shard_cap(self.emb.capacity),
            hot_capacity=(shard_cap(self.emb.hot_capacity)
                          if self.emb.is_tiered else None),
        )

    # -- routing helpers (shard-local code, used under shard_map) -----------

    def _owner(self, keys: U64, n_shards: int) -> jax.Array:
        _, h2 = u64.hash_pair(keys)
        own = (u64.fmix32(h2 ^ jnp.uint32(0x2545F491)) % jnp.uint32(n_shards)).astype(
            jnp.int32
        )
        return jnp.where(u64.is_empty(keys), n_shards, own)

    def _route(self, keys: U64, n_shards: int, cap: int):
        """Sort unique keys by owner; build [n_shards, cap] send buffers.

        Returns (send_hi, send_lo, slot_of_key [N] (-1 = overflow), order info)
        """
        n = keys.hi.shape[0]
        owner = self._owner(keys, n_shards)
        order = jnp.argsort(owner)
        o_s = owner[order]
        iota = jnp.arange(n, dtype=jnp.int32)
        is_new = jnp.concatenate([jnp.ones((1,), bool), o_s[1:] != o_s[:-1]])
        rank = iota - jax.lax.cummax(jnp.where(is_new, iota, -1))
        ok = (o_s < n_shards) & (rank < cap)
        slot = jnp.where(ok, o_s * cap + rank, n_shards * cap)
        send_hi = jnp.full((n_shards * cap,), u64.EMPTY_HI, jnp.uint32).at[slot].set(
            keys.hi[order], mode="drop"
        )
        send_lo = jnp.full((n_shards * cap,), u64.EMPTY_LO, jnp.uint32).at[slot].set(
            keys.lo[order], mode="drop"
        )
        # slot of each original key (for the return trip)
        key_slot = jnp.full((n,), -1, jnp.int32).at[order].set(
            jnp.where(ok, slot, -1)
        )
        return send_hi.reshape(n_shards, cap), send_lo.reshape(n_shards, cap), key_slot

    # -- shard-local bodies ---------------------------------------------------

    def _lookup_body(self, n_shards, cap, train, state, khi, klo,
                     promote=True, telemetry=None):
        """Executes per shard under shard_map: khi/klo are the LOCAL tokens'
        unique keys (padded with EMPTY).  Returns (state, rows, found, ovf).

        `promote=False` makes the read a PURE READER on tiered shards
        (no miss-path re-admission — the membership-query path).
        `telemetry` is a SHARD-LOCAL sink (the caller psums its total
        across the mesh — see `find_keys`)."""
        axis = self.axis_names
        local = self.local_embedding(n_shards)
        keys = U64(khi, klo)
        send_hi, send_lo, key_slot = self._route(keys, n_shards, cap)
        # dispatch keys to owners
        recv_hi = jax.lax.all_to_all(send_hi, axis, 0, 0, tiled=True)
        recv_lo = jax.lax.all_to_all(send_lo, axis, 0, 0, tiled=True)
        rk = U64(recv_hi.reshape(-1), recv_lo.reshape(-1))
        init = local.default_rows(rk)
        # owner-side table op through the handle — flat or tiered, the
        # embedding's wrap() picks; the inserter backend follows the
        # embedding config ('auto' -> fused Pallas on TPU)
        t = local.wrap(state)
        if train:
            res = t.find_or_insert(rk, init, telemetry=telemetry)
            state, rows = res.table.state, res.values
            present = res.found  # pre-existing (find_or_insert contract)
        else:
            # handle readers carry the backend: shard-local finds run the
            # fused find_scan pass when the embedding config picked kernel
            if isinstance(t, TieredHKVTable):
                fr = t.find(rk, promote=promote, telemetry=telemetry)
            else:
                fr = t.find(rk, telemetry=telemetry)
            rows = jnp.where(fr.found[:, None], fr.values, init[:, : local.dim])
            present = fr.found
            succ = getattr(fr, "table", None)  # tiered find promotes:
            if succ is not None:               # thread the successor state
                state = succ.state
        # return rows to requesters with the presence flag as one extra
        # column (exact in float: the flag is 0.0 or 1.0)
        rows = jnp.concatenate(
            [rows, present.astype(rows.dtype)[:, None]], axis=1
        ).reshape(n_shards, cap, local.dim + 1)
        back = jax.lax.all_to_all(rows, axis, 0, 0, tiled=True)
        back = back.reshape(n_shards * cap, local.dim + 1)
        ovf = jnp.sum((key_slot < 0) & ~u64.is_empty(keys))
        # overflowed / padded keys fall back to deterministic init rows
        fallback = local.default_rows(keys)
        routed = key_slot >= 0
        out = jnp.where(
            routed[:, None],
            back[jnp.clip(key_slot, 0), : local.dim],
            fallback,
        )
        found = routed & (back[jnp.clip(key_slot, 0), local.dim] > 0)
        return state, out, found, ovf

    def _grad_body(self, n_shards, cap, state, khi, klo, grads):
        axis = self.axis_names
        local = self.local_embedding(n_shards)
        keys = U64(khi, klo)
        send_hi, send_lo, key_slot = self._route(keys, n_shards, cap)
        gbuf = jnp.zeros((n_shards * cap, local.dim), grads.dtype).at[
            jnp.where(key_slot >= 0, key_slot, n_shards * cap)
        ].add(grads, mode="drop")
        recv_hi = jax.lax.all_to_all(send_hi, axis, 0, 0, tiled=True)
        recv_lo = jax.lax.all_to_all(send_lo, axis, 0, 0, tiled=True)
        recv_g = jax.lax.all_to_all(gbuf.reshape(n_shards, cap, -1), axis, 0, 0,
                                    tiled=True).reshape(n_shards * cap, -1)
        rk = U64(recv_hi.reshape(-1), recv_lo.reshape(-1))
        # owner-side dedupe across sources: same key from several data shards.
        # Compacted form (group g's key at slot g) so the segment sums align
        # with the uniques directly — no batch-sized g_sum[d.gid] re-broadcast
        n = rk.hi.shape[0]
        d = dedupe_keys(rk)
        uniq = U64(
            jnp.full((n,), u64.EMPTY_HI, jnp.uint32)
            .at[d.gid].set(rk.hi[d.idx_sorted]),
            jnp.full((n,), u64.EMPTY_LO, jnp.uint32)
            .at[d.gid].set(rk.lo[d.idx_sorted]),
        )
        g_sum = jax.ops.segment_sum(recv_g[d.idx_sorted], d.gid,
                                    num_segments=n, indices_are_sorted=True)
        # structured gradient step: ONE table op, and on the kernel backend
        # ONE fused update_scan launch per shard body
        t = local.wrap(state)
        s = t.session()
        s.update_rows(uniq, ops_mod.RowUpdate(local.optimizer, g_sum))
        return s.commit().state

    def _upsert_body(self, n_shards, cap, state, khi, klo, values,
                     telemetry=None):
        """insert_or_assign with caller values routed to owners; statuses
        routed back (the ShardedHKVTable protocol path)."""
        axis = self.axis_names
        local = self.local_embedding(n_shards)
        keys = U64(khi, klo)
        n = khi.shape[0]
        d = dedupe_keys(keys)
        send_hi, send_lo, key_slot = self._route(d.unique, n_shards, cap)
        # last-writer-wins within the batch: route the group's final row
        v_u = values[d.last_index]
        vbuf = jnp.zeros((n_shards * cap, values.shape[1]), values.dtype).at[
            jnp.where(key_slot >= 0, key_slot, n_shards * cap)
        ].set(v_u, mode="drop")
        recv_hi = jax.lax.all_to_all(send_hi, axis, 0, 0, tiled=True)
        recv_lo = jax.lax.all_to_all(send_lo, axis, 0, 0, tiled=True)
        recv_v = jax.lax.all_to_all(vbuf.reshape(n_shards, cap, -1), axis, 0, 0,
                                    tiled=True).reshape(n_shards * cap, -1)
        rk = U64(recv_hi.reshape(-1), recv_lo.reshape(-1))
        t = local.wrap(state)
        res = t.insert_or_assign(rk, recv_v, telemetry=telemetry)
        sbuf = res.status.astype(jnp.int32).reshape(n_shards, cap)
        back = jax.lax.all_to_all(sbuf, axis, 0, 0, tiled=True).reshape(-1)
        st_u = jnp.where(key_slot >= 0, back[jnp.clip(key_slot, 0)], 0)
        status = st_u[d.inverse].astype(jnp.int8)
        ovf = jnp.sum((key_slot < 0) & ~u64.is_empty(d.unique))
        return res.table.state, status, ovf

    def _assign_body(self, n_shards, cap, state, khi, klo, values):
        """Updater: route caller values to owners; owner-side assign (write
        existing keys only — misses are no-ops, the flat-table contract)."""
        axis = self.axis_names
        local = self.local_embedding(n_shards)
        d = dedupe_keys(U64(khi, klo))
        send_hi, send_lo, key_slot = self._route(d.unique, n_shards, cap)
        v_u = values[d.last_index]
        vbuf = jnp.zeros((n_shards * cap, values.shape[1]), values.dtype).at[
            jnp.where(key_slot >= 0, key_slot, n_shards * cap)
        ].set(v_u, mode="drop")
        recv_hi = jax.lax.all_to_all(send_hi, axis, 0, 0, tiled=True)
        recv_lo = jax.lax.all_to_all(send_lo, axis, 0, 0, tiled=True)
        recv_v = jax.lax.all_to_all(vbuf.reshape(n_shards, cap, -1), axis, 0, 0,
                                    tiled=True).reshape(n_shards * cap, -1)
        rk = U64(recv_hi.reshape(-1), recv_lo.reshape(-1))
        return local.wrap(state).assign(rk, recv_v).state

    def _erase_body(self, n_shards, cap, state, khi, klo):
        """Structural: route keys to owners; owner-side erase."""
        axis = self.axis_names
        local = self.local_embedding(n_shards)
        send_hi, send_lo, _slot = self._route(U64(khi, klo), n_shards, cap)
        recv_hi = jax.lax.all_to_all(send_hi, axis, 0, 0, tiled=True)
        recv_lo = jax.lax.all_to_all(send_lo, axis, 0, 0, tiled=True)
        rk = U64(recv_hi.reshape(-1), recv_lo.reshape(-1))
        return local.wrap(state).erase(rk).state

    # -- public API (call under `with mesh:` inside jit) ---------------------

    def create_sharded(self, mesh):
        n_shards = int(np.prod([mesh.shape[a] for a in self.axis_names]))
        local = self.local_embedding(n_shards)

        def body():
            return local.create().state

        specs = self.state_specs()
        return jax.jit(
            shard_map(body, mesh=mesh, in_specs=(), out_specs=specs,
                          check_vma=False)
        )()

    def state_specs(self):
        ax = self.axis_names
        # Derived from the state's own tree (works for flat HKVState AND
        # the tiered two-state pytree): array leaves shard their leading
        # (bucket/row) axis; scalar clocks/epoch are advanced in LOCKSTEP
        # (every shard executes the same op sequence) — replicated under
        # shard_map, not sharded.
        shape = jax.eval_shape(lambda: self.local_embedding(1).create().state)
        return jax.tree.map(
            lambda a: P(ax, *([None] * (a.ndim - 1))) if a.ndim >= 1 else P(),
            shape,
        )

    def _uniq(self, tokens):
        """Local dedupe: unique keys (EMPTY-padded) + inverse map."""
        d = dedupe_keys(self.emb.keys_of(tokens))
        return d.unique, d.inverse

    def _dp_axes(self, mesh):
        return tuple(a for a in mesh.axis_names if a in ("pod", "data"))

    def lookup(self, mesh, state, tokens, *, train: bool):
        """tokens: [B, S] (data-sharded). Returns (state, rows, overflow)."""
        n_shards = int(np.prod([mesh.shape[a] for a in self.axis_names]))
        dp = self._dp_axes(mesh)
        flat = tokens.reshape(-1)
        per_shard = max(flat.shape[0] // max(np.prod([mesh.shape[a] for a in dp]), 1), 1)
        cap = self._cap(per_shard, n_shards)

        def body(state, toks):
            uk, inv = self._uniq(toks.reshape(-1))
            state, rows, _found, ovf = self._lookup_body(
                n_shards, cap, train, state, uk.hi, uk.lo
            )
            return state, rows[inv], ovf.reshape(1)  # rank-1 for out_specs

        specs = self.state_specs()
        out = shard_map(
            body, mesh=mesh,
            in_specs=(specs, P(dp, None)),
            out_specs=(specs, P(dp, None), P(dp)),
            check_vma=False,
        )(state, tokens.reshape(tokens.shape[0], -1))
        state, rows, ovf = out
        return state, rows.reshape(tokens.shape + (self.emb.dim,)), jnp.sum(ovf)

    def find_keys(self, mesh, state, keys: U64, *, train: bool = False,
                  promote: bool = True, telemetry=None):
        """Key-level lookup: keys U64 [N] (N divisible by the dp world size).

        Returns (state, values [N, dim], found [N], overflow).  Misses
        return ZERO rows (the table-surface contract, unlike the embedding
        path's deterministic init fallback).

        `telemetry=` records ONE whole-mesh `OpTelemetry` into the sink
        (shard-local sinks inside the body, leaves psum-summed over every
        mesh axis, so the record is replicated and exact — DESIGN.md
        §Observability).  None is the exact pre-telemetry path."""
        n_shards = int(np.prod([mesh.shape[a] for a in self.axis_names]))
        dp = self._dp_axes(mesh)
        per_shard = max(keys.hi.shape[0] // max(np.prod([mesh.shape[a] for a in dp]), 1), 1)
        cap = self._cap(per_shard, n_shards)
        with_tel = telemetry is not None
        all_axes = tuple(mesh.axis_names)

        def body(state, khi, klo):
            sink = None
            if with_tel:
                obs_telemetry = _obs_tel()
                sink = obs_telemetry.TelemetrySink()
            d = dedupe_keys(U64(khi, klo))
            state, rows, found, ovf = self._lookup_body(
                n_shards, cap, train, state, d.unique.hi, d.unique.lo,
                promote=promote, telemetry=sink,
            )
            rows_o = rows[d.inverse]
            found_o = found[d.inverse] & ~u64.is_empty(U64(khi, klo))
            if not train:  # reader contract: zeros where not found
                rows_o = jnp.where(found_o[:, None], rows_o, 0.0)
            if with_tel:
                tel = obs_telemetry.psum_telemetry(sink.total(), all_axes)
                return state, rows_o, found_o, ovf.reshape(1), tel
            return state, rows_o, found_o, ovf.reshape(1)

        specs = self.state_specs()
        out_specs = (specs, P(dp, None), P(dp), P(dp))
        if with_tel:
            out_specs = out_specs + (P(),)  # psum-replicated counters
        out = shard_map(
            body, mesh=mesh,
            in_specs=(specs, P(dp), P(dp)),
            out_specs=out_specs,
            check_vma=False,
        )(state, keys.hi, keys.lo)
        if with_tel:
            state, rows, found, ovf, tel = out
            telemetry.record(
                "sharded_find_or_insert" if train else "sharded_find", tel)
        else:
            state, rows, found, ovf = out
        return state, rows, found, jnp.sum(ovf)

    def upsert_keys(self, mesh, state, keys: U64, values, *, telemetry=None):
        """Key-level insert_or_assign: values routed to owner shards.

        Returns (state, status [N] int8, overflow).  `telemetry=` records
        one whole-mesh `OpTelemetry` (same psum pattern as `find_keys`)."""
        n_shards = int(np.prod([mesh.shape[a] for a in self.axis_names]))
        dp = self._dp_axes(mesh)
        per_shard = max(keys.hi.shape[0] // max(np.prod([mesh.shape[a] for a in dp]), 1), 1)
        cap = self._cap(per_shard, n_shards)
        with_tel = telemetry is not None
        all_axes = tuple(mesh.axis_names)

        def body(state, khi, klo, v):
            sink = None
            if with_tel:
                obs_telemetry = _obs_tel()
                sink = obs_telemetry.TelemetrySink()
            state, status, ovf = self._upsert_body(
                n_shards, cap, state, khi, klo, v, telemetry=sink,
            )
            if with_tel:
                tel = obs_telemetry.psum_telemetry(sink.total(), all_axes)
                return state, status, ovf.reshape(1), tel
            return state, status, ovf.reshape(1)

        specs = self.state_specs()
        out_specs = (specs, P(dp), P(dp))
        if with_tel:
            out_specs = out_specs + (P(),)
        out = shard_map(
            body, mesh=mesh,
            in_specs=(specs, P(dp), P(dp), P(dp, None)),
            out_specs=out_specs,
            check_vma=False,
        )(state, keys.hi, keys.lo, values)
        if with_tel:
            state, status, ovf, tel = out
            telemetry.record("sharded_insert_or_assign", tel)
        else:
            state, status, ovf = out
        return state, status, jnp.sum(ovf)

    def assign_keys(self, mesh, state, keys: U64, values):
        """Key-level updater: values routed to owner shards; misses no-op."""
        n_shards = int(np.prod([mesh.shape[a] for a in self.axis_names]))
        dp = self._dp_axes(mesh)
        per_shard = max(keys.hi.shape[0] // max(np.prod([mesh.shape[a] for a in dp]), 1), 1)
        cap = self._cap(per_shard, n_shards)

        def body(state, khi, klo, v):
            return self._assign_body(n_shards, cap, state, khi, klo, v)

        specs = self.state_specs()
        return shard_map(
            body, mesh=mesh,
            in_specs=(specs, P(dp), P(dp), P(dp, None)),
            out_specs=specs,
            check_vma=False,
        )(state, keys.hi, keys.lo, values)

    def erase_keys(self, mesh, state, keys: U64):
        """Key-level structural erase routed to owner shards."""
        n_shards = int(np.prod([mesh.shape[a] for a in self.axis_names]))
        dp = self._dp_axes(mesh)
        per_shard = max(keys.hi.shape[0] // max(np.prod([mesh.shape[a] for a in dp]), 1), 1)
        cap = self._cap(per_shard, n_shards)

        def body(state, khi, klo):
            return self._erase_body(n_shards, cap, state, khi, klo)

        specs = self.state_specs()
        return shard_map(
            body, mesh=mesh,
            in_specs=(specs, P(dp), P(dp)),
            out_specs=specs,
            check_vma=False,
        )(state, keys.hi, keys.lo)

    def apply_grads(self, mesh, state, tokens, grads):
        n_shards = int(np.prod([mesh.shape[a] for a in self.axis_names]))
        dp = self._dp_axes(mesh)
        per_shard = max(
            tokens.size // max(np.prod([mesh.shape[a] for a in dp]), 1), 1
        )
        cap = self._cap(per_shard, n_shards)

        def body(state, toks, g):
            flat = toks.reshape(-1)
            g = g.reshape(-1, self.emb.dim)
            uk, inv = self._uniq(flat)
            n = flat.shape[0]
            # sum grads per unique (scatter to representative positions)
            g_uniq = jnp.zeros((n, self.emb.dim), g.dtype).at[inv].add(g)
            return self._grad_body(n_shards, cap, state, uk.hi, uk.lo, g_uniq)

        specs = self.state_specs()
        return shard_map(
            body, mesh=mesh,
            in_specs=(specs, P(dp, None), P(dp, None, None)),
            out_specs=specs,
            check_vma=False,
        )(state, tokens.reshape(tokens.shape[0], -1),
          grads.reshape(tokens.shape[0], -1, self.emb.dim))

    def _cap(self, per_shard_tokens: int, n_shards: int) -> int:
        c = int(per_shard_tokens * self.capacity_factor / n_shards)
        return max(8, -(-c // 8) * 8)


# =============================================================================
# ShardedHKVTable — the KVTable-protocol handle over the sharded engine
# =============================================================================


class ShardedFind(NamedTuple):
    values: jax.Array   # [N, dim] (zeros where not found)
    found: jax.Array    # bool [N]
    overflow: jax.Array  # int — keys that missed their routing budget
    # Successor handle: identical to the queried table for flat shards;
    # carries the promotion's effects when the shards are tiered (cold
    # hits re-admitted hot-side — DESIGN.md §2.5).  Callers that treat
    # find as a pure reader may ignore it.
    table: "ShardedHKVTable" = None


class ShardedUpsert(NamedTuple):
    table: "ShardedHKVTable"
    status: jax.Array   # int8 [N] merge status codes (0 where unrouted)
    overflow: jax.Array

    @property
    def ok(self) -> jax.Array:
        return (self.status >= 1) & (self.status <= 3)


class ShardedFindOrInsert(NamedTuple):
    table: "ShardedHKVTable"
    values: jax.Array
    found: jax.Array
    overflow: jax.Array


class ShardedSweep(NamedTuple):
    table: "ShardedHKVTable"
    swept: jax.Array     # int32 [] — entries removed across all shards


class ShardedEvictIf(NamedTuple):
    table: "ShardedHKVTable"
    # Per-shard coldest-first streams concatenated shard-major: lanes
    # [i*budget, (i+1)*budget) are shard i's rank order (2*budget per
    # shard when the shards are tiered).  The budget is PER SHARD —
    # sweeps are bucket-local, so per-shard application IS owner-routed.
    evicted: EvictionStream
    count: jax.Array     # int32 []


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ShardedHKVTable:
    """One table sharded over a mesh, behind the same handle discipline as
    `HKVTable`: sharded `state` is the only pytree leaf; the engine
    (`ShardedHKVEmbedding`) and mesh are static aux data.  Implements the
    `KVTable` protocol, so harness code is agnostic to whether a table
    lives on one device or a pod."""

    state: object                  # HKVState with leaves sharded over the mesh
    semb: ShardedHKVEmbedding
    mesh: object

    def tree_flatten(self):
        return (self.state,), (self.semb, self.mesh)

    @classmethod
    def tree_unflatten(cls, aux, children):
        semb, mesh = aux
        return cls(state=children[0], semb=semb, mesh=mesh)

    # -- construction ----------------------------------------------------------

    @classmethod
    def create(cls, mesh, emb: Optional[HKVEmbedding] = None, *,
               axis_names: Optional[tuple] = None,
               capacity_factor: float = 2.0, **emb_kwargs) -> "ShardedHKVTable":
        if emb is None:
            emb = HKVEmbedding(**emb_kwargs)
        semb = ShardedHKVEmbedding(
            emb=emb, axis_names=axis_names or tuple(mesh.axis_names),
            capacity_factor=capacity_factor,
        )
        return cls(state=semb.create_sharded(mesh), semb=semb, mesh=mesh)

    def with_state(self, state) -> "ShardedHKVTable":
        return dataclasses.replace(self, state=state)

    # -- static views ----------------------------------------------------------

    @property
    def n_shards(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.semb.axis_names]))

    @property
    def capacity(self) -> int:
        # realized capacity: per-shard rounding times shard count (both
        # tiers' slots when the local tables are tiered)
        local = self.semb.local_embedding(self.n_shards)
        return local.total_capacity * self.n_shards

    @property
    def dim(self) -> int:
        return self.semb.emb.dim

    # -- KVTable protocol ------------------------------------------------------

    def find(self, keys, *, promote: bool = True,
             telemetry=None) -> ShardedFind:
        """Lookup.  On tiered shards the default runs the miss-path
        promotion (keep `.table` to retain its effects); pass
        `promote=False` for the pure-reader form — serve-style callers
        that discard the successor handle should, or every lookup pays
        two structural upserts per shard that are then thrown away."""
        state, values, found, ovf = self.semb.find_keys(
            self.mesh, self.state, normalize_keys(keys), train=False,
            promote=promote, telemetry=telemetry,
        )
        return ShardedFind(values=values, found=found, overflow=ovf,
                           table=self.with_state(state))

    def insert_or_assign(self, keys, values, *,
                         telemetry=None) -> ShardedUpsert:
        state, status, ovf = self.semb.upsert_keys(
            self.mesh, self.state, normalize_keys(keys), values,
            telemetry=telemetry,
        )
        return ShardedUpsert(table=self.with_state(state), status=status,
                             overflow=ovf)

    def find_or_insert(self, keys, *, telemetry=None) -> ShardedFindOrInsert:
        """Admission-controlled lookup; misses insert the deterministic
        hash-derived init rows (routing caller init rows is not supported —
        owner shards recompute the init from the key)."""
        state, values, found, ovf = self.semb.find_keys(
            self.mesh, self.state, normalize_keys(keys), train=True,
            telemetry=telemetry,
        )
        return ShardedFindOrInsert(table=self.with_state(state), values=values,
                                   found=found, overflow=ovf)

    def assign(self, keys, values) -> "ShardedHKVTable":
        """Updater: write values of existing keys (misses no-op).  Keys
        beyond the per-destination routing budget are dropped (same
        overflow contract as every routed op; they surface in the next
        op's `overflow` metric rather than here)."""
        return self.with_state(self.semb.assign_keys(
            self.mesh, self.state, normalize_keys(keys), values))

    def erase(self, keys) -> "ShardedHKVTable":
        return self.with_state(self.semb.erase_keys(
            self.mesh, self.state, normalize_keys(keys)))

    def clear(self) -> "ShardedHKVTable":
        local = self.semb.local_embedding(self.n_shards)
        specs = self.semb.state_specs()

        def body(state):
            return local.wrap(state).clear().state

        return self.with_state(shard_map(
            body, mesh=self.mesh, in_specs=(specs,), out_specs=specs,
            check_vma=False,
        )(self.state))

    def contains(self, keys) -> jax.Array:
        # pure reader: no miss-path promotion on tiered shards (a
        # membership probe must not pay — or cause — structural motion)
        _state, _values, found, _ovf = self.semb.find_keys(
            self.mesh, self.state, normalize_keys(keys), train=False,
            promote=False,
        )
        return found

    # -- maintenance (sweeps are bucket-local: per-shard application IS
    # owner-routed — every key's owner shard sweeps its own buckets) ----------

    def erase_if(self, pred) -> ShardedSweep:
        local = self.semb.local_embedding(self.n_shards)
        specs = self.semb.state_specs()
        ax = self.semb.axis_names

        def body(state, p):
            r = local.wrap(state).erase_if(p)
            return r.table.state, r.swept.reshape(1)

        state, swept = shard_map(
            body, mesh=self.mesh,
            in_specs=(specs, jax.tree.map(lambda _: P(), pred)),
            out_specs=(specs, P(ax)), check_vma=False,
        )(self.state, pred)
        return ShardedSweep(table=self.with_state(state),
                            swept=jnp.sum(swept))

    def evict_if(self, pred, budget: int) -> ShardedEvictIf:
        local = self.semb.local_embedding(self.n_shards)
        specs = self.semb.state_specs()
        ax = self.semb.axis_names

        def body(state, p):
            r = local.wrap(state).evict_if(p, budget)
            return r.table.state, tuple(r.evicted), r.count.reshape(1)

        stream_specs = EvictionStream(
            key_hi=P(ax), key_lo=P(ax), values=P(ax, None),
            score_hi=P(ax), score_lo=P(ax), mask=P(ax))
        state, stream, count = shard_map(
            body, mesh=self.mesh,
            in_specs=(specs, jax.tree.map(lambda _: P(), pred)),
            out_specs=(specs, tuple(stream_specs), P(ax)), check_vma=False,
        )(self.state, pred)
        return ShardedEvictIf(table=self.with_state(state),
                              evicted=EvictionStream(*stream),
                              count=jnp.sum(count))

    def stats(self):
        """`TableStats` over the whole mesh.  Sharded state leaves are
        globally-addressable arrays and stats never hash keys, so the
        same jnp reductions run over all shards' buckets at once.  For
        tiered shards the hot/cold summaries combine with the inclusive
        duplicates deduped through `size()` (the shard_map probe)."""
        from repro.maintenance import stats as stats_mod  # deferred: layering

        st = self.state
        if isinstance(st, TieredState) or hasattr(st, "hot"):
            hot = stats_mod.stats_from_planes(
                st.hot.key_hi, st.hot.key_lo, st.hot.score_hi, st.hot.score_lo)
            cold = stats_mod.stats_from_planes(
                st.cold.key_hi, st.cold.key_lo, st.cold.score_hi,
                st.cold.score_lo)
            return stats_mod.combine_stats(hot, cold, size=self.size())
        return stats_mod.stats_from_planes(st.key_hi, st.key_lo,
                                           st.score_hi, st.score_lo)

    # -- export (the multi-host publish seam: per-shard drain, lanes
    # concatenated shard-major — ROADMAP item closed by PR 5) -----------------

    @property
    def num_buckets(self) -> int:
        """Export-space bucket count PER SHARD (the `export_batch`
        iteration bound): each call drains the same local bucket range on
        every shard and concatenates the lanes, so iterating
        [0, num_buckets) covers the whole mesh exactly once."""
        local = self.semb.local_embedding(self.n_shards)
        nb = local.config().num_buckets
        if local.is_tiered:
            nb += local.cold_config().num_buckets
        return nb

    def export_batch(self, bucket_start: int,
                     bucket_count: int) -> ExportResult:
        """Stream local buckets [start, start+count) of EVERY shard,
        concatenated shard-major (`bucket_count * S * n_shards` lanes with
        the liveness mask).  Owner routing partitions keys, so lanes are
        disjoint across shards; tiered shards apply their own inclusive-
        copy dedupe inside the shard body (`TieredHKVTable.export_batch`)."""
        local = self.semb.local_embedding(self.n_shards)
        specs = self.semb.state_specs()
        ax = self.semb.axis_names

        def body(state):
            return tuple(local.wrap(state).export_batch(
                bucket_start, bucket_count))

        out = shard_map(
            body, mesh=self.mesh, in_specs=(specs,),
            out_specs=(P(ax), P(ax), P(ax, None), P(ax), P(ax), P(ax)),
            check_vma=False,
        )(self.state)
        return ExportResult(*out)

    def size(self) -> jax.Array:
        specs = self.semb.state_specs()
        ax = self.semb.axis_names
        local = self.semb.local_embedding(self.n_shards)

        def body(state):
            # through the handle so tiered shards dedupe their inclusive
            # hot/cold copies exactly like a single-device tiered table
            return local.wrap(state).size().astype(jnp.int32).reshape(1)

        per_shard = shard_map(
            body, mesh=self.mesh, in_specs=(specs,), out_specs=P(ax),
            check_vma=False,
        )(self.state)
        return jnp.sum(per_shard)

    def load_factor(self) -> jax.Array:
        return self.size().astype(jnp.float32) / float(self.capacity)

    # -- embedding-layer delegates (the training path) -------------------------

    def lookup(self, tokens, *, train: bool):
        state, rows, ovf = self.semb.lookup(self.mesh, self.state, tokens,
                                            train=train)
        return self.with_state(state), rows, ovf

    def apply_grads(self, tokens, grads) -> "ShardedHKVTable":
        return self.with_state(
            self.semb.apply_grads(self.mesh, self.state, tokens, grads)
        )
