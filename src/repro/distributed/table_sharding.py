"""Distributed HKV table: all-to-all key routing over the device mesh.

The paper delegates multi-GPU sharding to application code (§7); this
module IS that application layer, built the way HugeCTR shards
model-parallel embeddings — and it is the piece that makes the multi-pod
dry-run meaningful for the technique:

  * Every shard owns an independent local HKV table of capacity/n_shards
    (its own buckets, digests, scores, values — all core invariants hold
    locally, including cache semantics at local λ=1.0).
  * A key's OWNER shard is a hash of the key (fmix of h2), so hot Zipfian
    keys scatter uniformly across shards.
  * Lookup/ingest: local dedupe -> capacity-bounded all_to_all of keys to
    owners -> owner-side find_or_insert -> all_to_all of value rows back.
    Wire cost per unique token: 8 B of key out, dim x 4 B of row back —
    strictly less than a vocab-parallel all-reduce at model-axis >= 2.
  * Gradients: the same routing in reverse (updater role).  Each unique
    key's grad is summed locally, routed to its single owner, then
    owner-side deduped across sources and applied ONCE via the sparse
    optimizer — no replica divergence, because no replicas exist.
  * Admission/eviction happen owner-side with unchanged semantics.

Skew handling: per-destination capacity = factor x fair share.  Uniques
beyond capacity fall back to deterministic init rows and are counted in
the returned `overflow` metric (they retry next step; a recurring hot key
is admitted on its next occurrence).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import merge as merge_mod
from repro.distributed.sharding import shard_map
from repro.core import ops as hkv_ops
from repro.core import u64
from repro.core.u64 import U64
from repro.embedding.dynamic import HKVEmbedding


@dataclasses.dataclass(frozen=True)
class ShardedHKVEmbedding:
    """HKVEmbedding sharded over mesh axes (default: every mesh axis)."""

    emb: HKVEmbedding              # GLOBAL capacity; local = capacity / n_shards
    axis_names: tuple              # mesh axes the table shards over
    capacity_factor: float = 2.0

    def local_embedding(self, n_shards: int) -> HKVEmbedding:
        local_cap = self.emb.capacity // n_shards
        local_cap = max(128, (local_cap // 128) * 128)
        return dataclasses.replace(self.emb, capacity=local_cap)

    # -- routing helpers (shard-local code, used under shard_map) -----------

    def _owner(self, keys: U64, n_shards: int) -> jax.Array:
        _, h2 = u64.hash_pair(keys)
        own = (u64.fmix32(h2 ^ jnp.uint32(0x2545F491)) % jnp.uint32(n_shards)).astype(
            jnp.int32
        )
        return jnp.where(u64.is_empty(keys), n_shards, own)

    def _route(self, keys: U64, n_shards: int, cap: int):
        """Sort unique keys by owner; build [n_shards, cap] send buffers.

        Returns (send_hi, send_lo, slot_of_key [N] (-1 = overflow), order info)
        """
        n = keys.hi.shape[0]
        owner = self._owner(keys, n_shards)
        order = jnp.argsort(owner)
        o_s = owner[order]
        iota = jnp.arange(n, dtype=jnp.int32)
        is_new = jnp.concatenate([jnp.ones((1,), bool), o_s[1:] != o_s[:-1]])
        rank = iota - jax.lax.cummax(jnp.where(is_new, iota, -1))
        ok = (o_s < n_shards) & (rank < cap)
        slot = jnp.where(ok, o_s * cap + rank, n_shards * cap)
        send_hi = jnp.full((n_shards * cap,), u64.EMPTY_HI, jnp.uint32).at[slot].set(
            keys.hi[order], mode="drop"
        )
        send_lo = jnp.full((n_shards * cap,), u64.EMPTY_LO, jnp.uint32).at[slot].set(
            keys.lo[order], mode="drop"
        )
        # slot of each original key (for the return trip)
        key_slot = jnp.full((n,), -1, jnp.int32).at[order].set(
            jnp.where(ok, slot, -1)
        )
        return send_hi.reshape(n_shards, cap), send_lo.reshape(n_shards, cap), key_slot

    # -- shard-local bodies ---------------------------------------------------

    def _lookup_body(self, n_shards, cap, train, state, khi, klo):
        """Executes per shard under shard_map: khi/klo are the LOCAL tokens'
        unique keys (padded with EMPTY)."""
        axis = self.axis_names
        local = self.local_embedding(n_shards)
        keys = U64(khi, klo)
        send_hi, send_lo, key_slot = self._route(keys, n_shards, cap)
        # dispatch keys to owners
        recv_hi = jax.lax.all_to_all(send_hi, axis, 0, 0, tiled=True)
        recv_lo = jax.lax.all_to_all(send_lo, axis, 0, 0, tiled=True)
        rk = U64(recv_hi.reshape(-1), recv_lo.reshape(-1))
        cfg = local.config()
        init = local.default_rows(rk)
        if train:
            # owner-side structural op; backend follows the local embedding
            # config ('auto' -> the fused Pallas path on TPU, DESIGN.md §4)
            res = hkv_ops.find_or_insert(state, cfg, rk, init,
                                         backend=self.emb.backend)
            state, rows = res.state, res.values
        else:
            fr = hkv_ops.find(state, cfg, rk)
            rows = jnp.where(fr.found[:, None], fr.values, init[:, : local.dim])
        # return rows to requesters
        rows = rows.reshape(n_shards, cap, local.dim)
        back = jax.lax.all_to_all(rows, axis, 0, 0, tiled=True)
        back = back.reshape(n_shards * cap, local.dim)
        ovf = jnp.sum((key_slot < 0) & ~u64.is_empty(keys))
        # overflowed / padded keys fall back to deterministic init rows
        fallback = local.default_rows(keys)
        out = jnp.where(
            (key_slot >= 0)[:, None],
            back[jnp.clip(key_slot, 0)],
            fallback,
        )
        return state, out, ovf

    def _grad_body(self, n_shards, cap, state, khi, klo, grads):
        axis = self.axis_names
        local = self.local_embedding(n_shards)
        keys = U64(khi, klo)
        send_hi, send_lo, key_slot = self._route(keys, n_shards, cap)
        gbuf = jnp.zeros((n_shards * cap, local.dim), grads.dtype).at[
            jnp.where(key_slot >= 0, key_slot, n_shards * cap)
        ].add(grads, mode="drop")
        recv_hi = jax.lax.all_to_all(send_hi, axis, 0, 0, tiled=True)
        recv_lo = jax.lax.all_to_all(send_lo, axis, 0, 0, tiled=True)
        recv_g = jax.lax.all_to_all(gbuf.reshape(n_shards, cap, -1), axis, 0, 0,
                                    tiled=True).reshape(n_shards * cap, -1)
        rk = U64(recv_hi.reshape(-1), recv_lo.reshape(-1))
        # owner-side dedupe across sources: same key from several data shards
        n = rk.hi.shape[0]
        keys_s, idx_s, gid, _c, _l, rep = merge_mod._dedupe_sort(rk)
        g_sum = jax.ops.segment_sum(recv_g[idx_s], gid, num_segments=n)[gid]
        uk = u64.select(rep, keys_s, u64.empty_sentinel((n,)))
        cfg = local.config()
        from repro.core import find as find_mod

        loc = find_mod.locate(state, cfg, uk)
        rows = state.values[jnp.clip(loc.row, 0, state.values.shape[0] - 1)]
        new_rows = local.optimizer.apply(rows, g_sum, local.dim)
        return hkv_ops.assign(state, cfg, uk, new_rows)

    # -- public API (call under `with mesh:` inside jit) ---------------------

    def create_sharded(self, mesh):
        n_shards = int(np.prod([mesh.shape[a] for a in self.axis_names]))
        local = self.local_embedding(n_shards)

        def body():
            return local.create()

        specs = self.state_specs()
        return jax.jit(
            shard_map(body, mesh=mesh, in_specs=(), out_specs=specs,
                          check_vma=False)
        )()

    def state_specs(self):
        from repro.core.table import HKVState

        ax = self.axis_names
        # clocks/epoch are scalars advanced in LOCKSTEP (every shard executes
        # the same op sequence) — replicated under shard_map, not sharded
        return HKVState(
            key_hi=P(ax, None), key_lo=P(ax, None), digests=P(ax, None),
            score_hi=P(ax, None), score_lo=P(ax, None), values=P(ax, None),
            clock_hi=P(), clock_lo=P(), epoch=P(),
        )

    def _uniq(self, tokens):
        """Local dedupe: unique keys (EMPTY-padded) + inverse map."""
        keys = self.emb.keys_of(tokens)
        n = keys.hi.shape[0]
        keys_s, idx_s, gid, _c, _l, rep = merge_mod._dedupe_sort(keys)
        uk = u64.select(rep, keys_s, u64.empty_sentinel((n,)))
        # token i -> position of its group representative in sorted space
        rep_pos = jax.ops.segment_min(
            jnp.arange(n, dtype=jnp.int32), gid, num_segments=n
        )
        inv = jnp.zeros((n,), jnp.int32).at[idx_s].set(rep_pos[gid])
        return uk, inv

    def lookup(self, mesh, state, tokens, *, train: bool):
        """tokens: [B, S] (data-sharded). Returns (state, rows, overflow)."""
        n_shards = int(np.prod([mesh.shape[a] for a in self.axis_names]))
        dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        flat = tokens.reshape(-1)
        per_shard = max(flat.shape[0] // max(np.prod([mesh.shape[a] for a in dp]), 1), 1)
        cap = self._cap(per_shard, n_shards)

        def body(state, toks):
            uk, inv = self._uniq(toks.reshape(-1))
            state, rows, ovf = self._lookup_body(
                n_shards, cap, train, state, uk.hi, uk.lo
            )
            return state, rows[inv], ovf.reshape(1)  # rank-1 for out_specs

        specs = self.state_specs()
        out = shard_map(
            body, mesh=mesh,
            in_specs=(specs, P(dp, None)),
            out_specs=(specs, P(dp, None), P(dp)),
            check_vma=False,
        )(state, tokens.reshape(tokens.shape[0], -1))
        state, rows, ovf = out
        return state, rows.reshape(tokens.shape + (self.emb.dim,)), jnp.sum(ovf)

    def apply_grads(self, mesh, state, tokens, grads):
        n_shards = int(np.prod([mesh.shape[a] for a in self.axis_names]))
        dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        per_shard = max(
            tokens.size // max(np.prod([mesh.shape[a] for a in dp]), 1), 1
        )
        cap = self._cap(per_shard, n_shards)

        def body(state, toks, g):
            flat = toks.reshape(-1)
            g = g.reshape(-1, self.emb.dim)
            uk, inv = self._uniq(flat)
            n = flat.shape[0]
            # sum grads per unique (scatter to representative positions)
            g_uniq = jnp.zeros((n, self.emb.dim), g.dtype).at[inv].add(g)
            return self._grad_body(n_shards, cap, state, uk.hi, uk.lo, g_uniq)

        specs = self.state_specs()
        return shard_map(
            body, mesh=mesh,
            in_specs=(specs, P(dp, None), P(dp, None, None)),
            out_specs=specs,
            check_vma=False,
        )(state, tokens.reshape(tokens.shape[0], -1),
          grads.reshape(tokens.shape[0], -1, self.emb.dim))

    def _cap(self, per_shard_tokens: int, n_shards: int) -> int:
        c = int(per_shard_tokens * self.capacity_factor / n_shards)
        return max(8, -(-c // 8) * 8)
