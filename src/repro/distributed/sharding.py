"""Parameter/activation PartitionSpecs: the Megatron mapping, path-matched.

Column-parallel into the block, row-parallel out — one logical all-reduce
per block, inserted by GSPMD:

  embed table [V, d]          -> (model, None)        vocab-parallel
  head        [d, V]          -> (None, model)
  wq/wk/wv    [d, H*hd]       -> (None, model)        heads sharded
  wo          [H*hd, d]       -> (model, None)
  ffn_wi      [d, ff]         -> (None, model)
  ffn_wo      [ff, d]         -> (model, None)
  moe wi/wo   [E, ., .]       -> (model, None, None)  expert-parallel
  ssm in/up   [d, proj]       -> (None, model)
  ssm out/down[proj, d]       -> (model, None)
  norms/bias/vectors          -> replicated

Stacked layer dims (repeats, count) prepend None.  Batch inputs shard over
the DP axes (pod folds into data); vocab/MoE/TP all live on "model".
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """`jax.shard_map` across JAX generations.

    Newer releases expose it as `jax.shard_map(..., check_vma=...)`; older
    ones as `jax.experimental.shard_map.shard_map(..., check_rep=...)` (the
    same replication-checking switch under its pre-rename spelling).
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


_RULES = {
    # leaf name -> base spec (without leading stack dims)
    "table": ("model", None),
    "head": (None, "model"),
    "wq": (None, "model"),
    "wk": (None, "model"),
    "wv": (None, "model"),
    "bq": ("model",),
    "bk": ("model",),
    "bv": ("model",),
    "wo": ("model", None),
    "ffn_wi": (None, "model"),
    "ffn_wo": ("model", None),
    "router": (None, None),
    "wi": ("model", None, None),     # MoE experts
    "in_proj": (None, "model"),
    "out_proj": ("model", None),
    "conv_w": (None, "model"),
    "conv_b": ("model",),
    "out_norm": ("model",),
    "up": (None, "model"),
    "down": ("model", None),
    "wgate": ("model", None),
    "wx": (None, "model"),
    "out": ("model", None),
    "r": (None, None, None),
}
# MoE wo [E, ff, d] collides with attention "wo" by name; disambiguated by rank.
_MOE_WO = ("model", None, None)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def spec_for(path, leaf, fsdp: bool = True) -> P:
    name = _leaf_name(path)
    in_moe = any(
        isinstance(e, jax.tree_util.DictKey) and str(e.key) == "moe" for e in path
    )
    base = _RULES.get(name)
    if name == "wo" and in_moe:
        base = _MOE_WO
    if name == "wi" and not in_moe:
        base = None
    if base is None:
        return P()  # replicated (norms, scalars, A_log, D, ...)
    extra = leaf.ndim - len(base)
    if extra < 0:
        return P()
    base = list(base)
    # FSDP: additionally shard one free dim of every >=2D weight over "data"
    # (ZeRO-3 via GSPMD: params gather per layer inside the scan).  The
    # embedding table and LM head are exempt — their free dim feeds the
    # vocab-parallel gather/psum pattern and replicating d there costs only
    # ~vocab*d/|model| per device.
    # divisibility guard: the production mesh has |data|=|model|=16; a named
    # axis on a non-divisible dim is a pjit error (e.g. mLSTM block-diagonal
    # [G, 4, 4] projections) — drop to replicated for that dim
    for i, b in enumerate(base):
        if b is not None and leaf.shape[extra + i] % 16 != 0:
            base[i] = None
    if fsdp and name not in ("table", "head") and leaf.ndim >= 2:
        for i, b in enumerate(base):
            if b is None and leaf.shape[extra + i] % 16 == 0:
                base[i] = "data"
                break
    return P(*((None,) * extra + tuple(base)))


def param_specs(params, fsdp: bool = True) -> dict:
    """Pytree of PartitionSpecs matching `params` (works on ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: spec_for(p, l, fsdp), params
    )


def param_shardings(mesh, params):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params)
    )


def batch_spec(mesh) -> P:
    """Token batches: sharded over every DP axis."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return P(dp, None)


def maybe_constrain(x, *spec):
    """with_sharding_constraint that no-ops outside a mesh context.

    Model code calls this to pin activation layouts (EP dispatch buffers,
    attention intermediates) when compiled under a mesh; smoke tests and
    single-device runs pass through untouched.  Axes named in `spec` that
    the ambient mesh lacks are dropped.
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        cleaned = tuple(
            a if (a is None or a in mesh.axis_names) else None for a in spec
        )
        return jax.lax.with_sharding_constraint(x, P(*cleaned))
    except Exception:  # noqa: BLE001 — constraint is best-effort by design
        return x


def table_specs(state) -> dict:
    """HKV table state: buckets sharded over 'model', clock/epoch replicated.

    Used by the replicated-over-data layout (vocab-parallel analogue); the
    all-to-all layout in distributed.table_sharding shards over all axes.
    """
    from repro.core.table import HKVState

    return HKVState(
        key_hi=P("model", None),
        key_lo=P("model", None),
        digests=P("model", None),
        score_hi=P("model", None),
        score_lo=P("model", None),
        values=P("model", None),
        clock_hi=P(),
        clock_lo=P(),
        epoch=P(),
    )


def decode_state_specs(mesh, state_shapes, kv_heads_divisible: bool) -> object:
    """KV caches: shard heads over model when divisible, else the sequence
    dim (decode-SP: GSPMD turns softmax reductions into partial+all-reduce).
    Recurrent SSM states shard batch over data and heads/channels over model."""

    def spec(path, leaf):
        name = _leaf_name(path)
        if name in ("k", "v"):      # [stack..., B, S, Hkv, dh] KV cache
            lead = leaf.ndim - 4
            if kv_heads_divisible:
                return P(*((None,) * lead), "data", None, "model", None)
            return P(*((None,) * lead), "data", "model", None, None)
        if name == "gla":           # [stack..., B, H, N, P] — shard the state
            # dim N (uniformly >= mesh model size: 64 for mamba2, 1024 for
            # mLSTM) rather than heads (xLSTM has only 4)
            lead = leaf.ndim - 4
            return P(*((None,) * lead), "data", None, "model", None)
        if name == "conv":          # [stack..., B, W, d_inner]
            lead = leaf.ndim - 3
            return P(*((None,) * lead), "data", None, "model")
        if name in ("c", "n", "h", "m"):  # sLSTM [stack..., B, H, pd]
            lead = leaf.ndim - 3
            return P(*((None,) * lead), "data", None, "model")
        if name == "pos":
            return P()
        if leaf.ndim >= 1:
            return P(*((None,) * leaf.ndim))
        return P()

    return jax.tree_util.tree_map_with_path(spec, state_shapes)
