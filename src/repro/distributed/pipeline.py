"""GPipe-style pipeline parallelism over the "pod" axis (ppermute ring).

Off by default — the production layout carries DP over pods — but available
for deployments where the inter-pod DCN link cannot sustain full-gradient
all-reduce: pipeline crossing the slow axis moves only activations
(microbatch x d_model per hop) instead of the full gradient set.

Schedule: forward-fill / drain with M microbatches over K stages
(utilization M/(M+K-1)); stage p applies its layer slice then
collective_permute's activations to stage p+1.  Implemented as a shard_map
over the pipeline axis with a static schedule loop — every step is a
(compute, ppermute) pair XLA can overlap.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_map


def pipeline_apply(mesh, axis: str, stage_fn, stage_params, x_microbatches):
    """stage_fn(params_slice, x) -> x, applied across `axis` stages.

    stage_params: pytree with leading stage axis (sharded over `axis`).
    x_microbatches: [M, mb, ...] microbatched input, replicated per stage.
    Returns the pipeline output [M, mb, ...].
    """
    k = mesh.shape[axis]

    def body(params, xs):
        params = jax.tree.map(lambda a: a[0], params)  # my stage's slice
        m = xs.shape[0]
        stage = jax.lax.axis_index(axis)
        n_ticks = m + k - 1
        perm = [(i, (i + 1) % k) for i in range(k)]

        def tick(carry, t):
            buf, out = carry
            # which microbatch enters stage 0 at tick t
            mb_idx = jnp.clip(t, 0, m - 1)
            incoming = jnp.where(stage == 0, 1, 0)
            x_in = jnp.where(incoming, xs[mb_idx], buf)
            active = (t - stage >= 0) & (t - stage < m)
            y = stage_fn(params, x_in)
            y = jnp.where(active, y, x_in)
            # last stage writes its finished microbatch to the output slot
            done_idx = jnp.clip(t - (k - 1), 0, m - 1)
            write = active & (stage == k - 1)
            out = jax.lax.cond(
                write,
                lambda o: jax.lax.dynamic_update_index_in_dim(o, y, done_idx, 0),
                lambda o: o,
                out,
            )
            nxt = jax.lax.ppermute(y, axis, perm)
            return (nxt, out), None

        buf0 = jnp.zeros_like(xs[0])
        out0 = jnp.zeros_like(xs)
        (_, out), _ = jax.lax.scan(tick, (buf0, out0), jnp.arange(n_ticks))
        # only the last stage holds the real outputs; broadcast via masked psum
        out = jax.lax.psum(jnp.where(stage == k - 1, out, 0.0), axis)
        return out

    in_specs = (
        jax.tree.map(lambda _: P(axis), stage_params),
        P(),  # microbatches replicated across stages
    )
    return shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=P(), check_vma=False
    )(stage_params, x_microbatches)
